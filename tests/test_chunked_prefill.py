"""Chunked-prefill tests (runtime.serve_loop.Scheduler with
prefill_chunk= + runtime.steps.make_chunk_prefill_step).

Coverage layers, mirroring tests/test_scheduler.py:

* Golden stub-model tests: chunked continuous serving emits exactly the
  greedy continuation per request, chunk steps interleave 1:1 with the
  resident lanes' decode steps (the head-of-line-blocking fix), and the
  PREFILLING lane lifecycle (admission -> chunks -> first token -> decode)
  is observable through chunk_steps / prefill_calls / call order.
* Property sweep: random (prompt_len, quota) workloads x chunk sizes —
  chunked == unchunked continuous == static, token for token; no token
  lost or duplicated.
* Real-model invariants on gemma2-2b-reduced (prompts cross the
  local_attn ring window): chunked == unchunked greedy parity across
  chunk sizes incl. ragged final chunks and chunk > prompt; a chunk step
  never perturbs co-resident lanes' caches (per-chunk slot-insert
  BIT-identity, f32 and int8 caches); a recompile guard (the jitted chunk
  / decode steps trace exactly once across admissions and chunk counts);
  paged chunked serving (block growth per chunk, parity, no block leak);
  and the deploy-int8 path for both kv-bit widths (calibrated int8 KV
  round-trips storage exactly, so chunked parity is preserved).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.runtime import (BlockPool, Request, blocks_for_tokens, serve,
                           serve_continuous)
from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                 make_decode_step, make_prefill_step)
from serve_testlib import golden as _golden
from serve_testlib import next_arr as _next_arr
from serve_testlib import onehot as _onehot

pytestmark = pytest.mark.serve


class StubChunkModel:
    """Deterministic next_token = (2 * tok + 1) % VOCAB with call-order
    recording, for both the chunk step and the decode step. The scheduler
    reads logits[:, -1:], i.e. the LAST chunk column — the final real token
    of a left-padded chunk row."""

    def __init__(self):
        self.calls = []                 # "chunk" / "decode" in issue order
        self.chunk_resets = []
        self.chunk_positions = []

    def init_cache(self, batch):
        return {"kv": jnp.zeros((batch, 4), jnp.float32)}

    def admit(self, tokens, positions, admit_mask, cache):
        self.calls.append("admit")
        return _onehot(_next_arr(tokens)), cache

    def chunk(self, tokens, positions, reset_mask, cache):
        self.calls.append("chunk")
        self.chunk_resets.append(np.asarray(reset_mask).copy())
        self.chunk_positions.append(np.asarray(positions).copy())
        return _onehot(_next_arr(tokens)), cache

    def decode(self, tokens, pos, cache):
        self.calls.append("decode")
        return _onehot(_next_arr(tokens)), cache


def _serve_chunked(requests, batch_slots=4, prefill_chunk=4, **kw):
    m = StubChunkModel()
    stats = serve_continuous(m.admit, m.decode, m.init_cache, requests,
                             batch_slots=batch_slots, chunk_fn=m.chunk,
                             prefill_chunk=prefill_chunk, **kw)
    return m, stats


def _reqs(specs):
    return [Request(rid=i, prompt=np.arange(1, n + 1, dtype=np.int32),
                    max_new_tokens=q) for i, (n, q) in enumerate(specs)]


class TestGoldenChunked:
    def test_greedy_continuation_matches_golden(self):
        reqs = [Request(rid=i, prompt=np.asarray([3 + i] * (5 + i)),
                        max_new_tokens=6) for i in range(3)]
        m, stats = _serve_chunked(reqs, prefill_chunk=3)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 6)
            assert r.done
        assert stats.tokens_generated == 18
        # 7-token longest prompt at chunk 3 -> 3 chunk rounds (lanes share
        # chunk calls; the longest lane sets the count)
        assert stats.chunk_steps == 3
        assert stats.prefill_calls == stats.chunk_steps

    def test_chunks_interleave_with_resident_decodes(self):
        """A 1-token resident decodes BETWEEN the chunks of a 9-token
        prompt admitted next to it — the stall chunked prefill removes."""
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=8),
                Request(rid=1, prompt=np.asarray([5] * 9),
                        max_new_tokens=2)]
        m, stats = _serve_chunked(reqs, batch_slots=2, prefill_chunk=3)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        # r0 completes its prefill in chunk round 1; rounds 2 and 3 carry
        # r1's remaining chunks with r0's decode steps BETWEEN them
        assert m.calls[:6] == ["chunk", "decode", "chunk", "decode",
                               "chunk", "decode"]
        assert stats.chunk_steps == 3

    def test_reset_mask_marks_first_chunk_only(self):
        reqs = _reqs([(7, 1)])
        m, _ = _serve_chunked(reqs, batch_slots=1, prefill_chunk=3)
        resets = [bool(r[0]) for r in m.chunk_resets]
        assert resets == [True, False, False]
        # chunk rows carry absolute positions off..off+c-1, left-padded
        starts = [int(p[0][p[0] >= 0].min()) for p in m.chunk_positions]
        ends = [int(p[0].max()) for p in m.chunk_positions]
        assert starts == [0, 3, 6] and ends == [2, 5, 6]

    def test_chunk_wider_than_prompt_is_single_round(self):
        reqs = _reqs([(4, 3), (2, 3)])
        m, stats = _serve_chunked(reqs, batch_slots=2, prefill_chunk=16)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 3)
        assert stats.chunk_steps == 1
        assert "admit" not in m.calls    # chunked mode never calls admit_fn

    def test_zero_quota_and_quota_one(self):
        reqs = [Request(rid=0, prompt=np.asarray([3, 4]), max_new_tokens=0),
                Request(rid=1, prompt=np.asarray([4] * 5), max_new_tokens=1),
                Request(rid=2, prompt=np.asarray([6]), max_new_tokens=2)]
        m, stats = _serve_chunked(reqs, batch_slots=1, prefill_chunk=2)
        assert reqs[0].tokens_out == [] and reqs[0].done
        assert reqs[1].tokens_out == _golden(reqs[1].prompt, 1)
        assert reqs[2].tokens_out == _golden(reqs[2].prompt, 2)
        # quota-1 lane retires straight off its final chunk's logits; the
        # single lane then serves r2 (FIFO)
        assert stats.tokens_generated == 3

    def test_empty_prompt_raises(self):
        with pytest.raises(ValueError, match="empty prompt"):
            _serve_chunked([Request(rid=0, prompt=np.asarray([], np.int32),
                                    max_new_tokens=2)], batch_slots=1)

    def test_invalid_configs_raise(self):
        reqs = _reqs([(3, 1)])
        with pytest.raises(ValueError, match="prefill_chunk"):
            _serve_chunked(reqs, prefill_chunk=0)
        m = StubChunkModel()
        with pytest.raises(ValueError, match="chunk_fn"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, prefill_chunk=4)
        with pytest.raises(ValueError, match="continuous-scheduler"):
            serve(None, None, m.decode, m.init_cache, None, reqs,
                  scheduler="static", batch_slots=1, prefill_chunk=4)


class TestChunkedProperties:
    def test_chunked_matches_unchunked_sweep(self):
        """Seeded sweep over workloads x chunk sizes: chunked == unchunked
        continuous == golden, full retirement, no token lost."""
        rng = np.random.RandomState(0)
        for _ in range(20):
            n = rng.randint(1, 8)
            specs = [(rng.randint(1, 12), rng.randint(0, 6))
                     for _ in range(n)]
            slots = rng.randint(1, 4)
            chunk = rng.randint(1, 6)
            chunked = _reqs(specs)
            m, stats = _serve_chunked(chunked, batch_slots=slots,
                                      prefill_chunk=chunk)
            unchunked = _reqs(specs)
            m2 = StubChunkModel()
            serve_continuous(m2.admit, m2.decode, m2.init_cache, unchunked,
                             batch_slots=slots)
            for c, u in zip(chunked, unchunked):
                assert c.done
                assert c.tokens_out == u.tokens_out
                assert c.tokens_out == _golden(c.prompt,
                                               max(c.max_new_tokens, 0))
            assert stats.tokens_generated == sum(
                len(r.tokens_out) for r in chunked)


# ---------------------------------------------------------------------------
# Real-model invariants (gemma2-2b-reduced: local_attn ring window 16, so
# prompts of ~24 tokens cross the window mid-chunk)
# ---------------------------------------------------------------------------

MAX_LEN = 32


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    return cfg, params


_STEP_CACHE = {}


def _steps(cfg, ctx_factory=None):
    key = (cfg.name, ctx_factory)
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = (
            jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_chunk_prefill_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_prefill_step(cfg, ctx_factory=ctx_factory)))
    return _STEP_CACHE[key]


def _serve_real(cfg, params, reqs, *, kv_bits=16, batch_slots=2, chunk=0,
                ctx_factory=None, paged=False, num_blocks=None):
    admit, chunkstep, decode, prefill = _steps(cfg, ctx_factory)
    pool = None
    if paged:
        nb_lane = blocks_for_tokens(MAX_LEN, 8)
        num_blocks = num_blocks or batch_slots * nb_lane
        pool = BlockPool(num_blocks, 8, batch_slots, nb_lane)

    def init(b):
        if not paged:
            return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                  kv_bits=kv_bits)
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=kv_bits, paged=True, block_size=8,
                              num_blocks=num_blocks, mapped=False)

    stats = serve(prefill, admit, decode, init, params, reqs,
                  scheduler="continuous", batch_slots=batch_slots,
                  max_len=MAX_LEN, block_pool=pool,
                  chunk_step=chunkstep if chunk else None,
                  prefill_chunk=chunk or None)
    return stats, pool


def _mk_reqs(seed, cfg, lens_quotas):
    rng = np.random.RandomState(seed)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size, size=n)
                    .astype(np.int32),
                    max_new_tokens=q)
            for i, (n, q) in enumerate(lens_quotas)]


def _lane_bytes(cache, lane):
    parts = []
    for c in cache["scan"]:
        parts.extend(np.asarray(leaf[:, lane]).tobytes() for leaf in c)
    for c in cache["tail"]:
        parts.extend(np.asarray(leaf[lane]).tobytes() for leaf in c)
    return b"".join(parts)


# ragged final chunks (3, 5 do not divide 24) + chunk wider than prompt
CHUNK_SIZES = [3, 5, 40]
SPEC = [(5, 2), (24, 6), (3, 1), (7, 4), (4, 8), (6, 2)]


class TestRealModelChunked:
    def test_chunked_matches_unchunked_across_chunk_sizes(self, tiny):
        """Greedy parity on a ragged skewed workload whose 24-token prompt
        crosses the local_attn ring window (16) mid-chunk."""
        cfg, params = tiny
        base = _mk_reqs(3, cfg, SPEC)
        _serve_real(cfg, params, base)
        for chunk in CHUNK_SIZES:
            reqs = _mk_reqs(3, cfg, SPEC)
            stats, _ = _serve_real(cfg, params, reqs, chunk=chunk)
            for b, r in zip(base, reqs):
                assert b.tokens_out == r.tokens_out, (chunk, r.rid)
                assert r.done
            assert stats.chunk_steps > 0
            assert stats.chunk_steps == stats.prefill_calls

    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_chunk_step_preserves_other_lanes_bitwise(self, tiny, kv_bits):
        """Per-chunk slot-insert bit-identity: appending a chunk to lane 1
        leaves lanes 0 and 2 BIT-identical across every cache leaf — for
        the f32 cache and the int8 QuantKVCache, for the resetting first
        chunk AND a follow-up chunk."""
        cfg, params = tiny
        admit, chunkstep, decode, _ = _steps(cfg)
        B, T, C = 3, 6, 4
        rng = np.random.RandomState(1)
        cache = tfm.init_cache(cfg, B, MAX_LEN, dtype=jnp.float32,
                               kv_bits=kv_bits)
        toks = rng.randint(1, cfg.vocab_size, size=(B, T)).astype(np.int32)
        posm = np.tile(np.arange(T, dtype=np.int32), (B, 1))
        logits, cache = admit(params, toks, posm, np.ones((B,), bool), cache)
        cur = np.asarray(jnp.argmax(logits[:, -1:], -1), np.int32)
        pos = np.full((B, 1), T, np.int32)
        for _ in range(2):
            logits, cache = decode(params, cur, pos, cache)
            cur = np.asarray(jnp.argmax(logits, -1), np.int32)
            pos = pos + 1
        off = 0
        for first in (True, False):
            before = {i: _lane_bytes(cache, i) for i in range(B)}
            ctoks = np.zeros((B, C), np.int32)
            cposm = np.full((B, C), -1, np.int32)
            ctoks[1] = rng.randint(1, cfg.vocab_size, size=C)
            cposm[1] = np.arange(off, off + C)
            reset = np.asarray([False, first, False])
            _, cache = chunkstep(params, ctoks, cposm, reset, cache)
            after = {i: _lane_bytes(cache, i) for i in range(B)}
            assert after[0] == before[0], ("lane 0 perturbed", first)
            assert after[2] == before[2], ("lane 2 perturbed", first)
            assert after[1] != before[1]
            off += C

    def test_chunked_equals_monolithic_cache_state(self, tiny):
        """Feeding a prompt in chunks leaves the lane's cache positions and
        next-token logits matching one monolithic slot-insert prefill."""
        cfg, params = tiny
        admit, chunkstep, _, _ = _steps(cfg)
        rng = np.random.RandomState(7)
        n, C = 11, 4
        prompt = rng.randint(1, cfg.vocab_size, size=n).astype(np.int32)

        cache_m = tfm.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
        lm, cache_m = admit(params, prompt[None, :],
                            np.arange(n, dtype=np.int32)[None, :],
                            np.ones((1,), bool), cache_m)

        cache_c = tfm.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
        for off in range(0, n, C):
            c = min(C, n - off)
            toks = np.zeros((1, C), np.int32)
            posm = np.full((1, C), -1, np.int32)
            toks[0, C - c:] = prompt[off:off + c]
            posm[0, C - c:] = np.arange(off, off + c)
            lc, cache_c = chunkstep(params, toks, posm,
                                    np.asarray([off == 0]), cache_c)

        assert int(jnp.argmax(lm[0, -1])) == int(jnp.argmax(lc[0, -1]))
        for leaf_m, leaf_c in zip(
                [c.pos for c in cache_m["scan"]] +
                [c.pos for c in cache_m["tail"]],
                [c.pos for c in cache_c["scan"]] +
                [c.pos for c in cache_c["tail"]]):
            np.testing.assert_array_equal(np.asarray(leaf_m),
                                          np.asarray(leaf_c))

    def test_no_recompiles_across_chunks_and_admissions(self, tiny):
        """The jitted chunk / decode steps trace exactly once across many
        admissions, chunk counts and ragged final chunks."""
        cfg, params = tiny
        traces = {"chunk": 0, "decode": 0}
        base_chunk = make_chunk_prefill_step(cfg)
        base_decode = make_decode_step(cfg)

        def chunk_fn(params, t, pm, m, c):
            traces["chunk"] += 1
            return base_chunk(params, t, pm, m, c)

        def decode_fn(params, t, p, c):
            traces["decode"] += 1
            return base_decode(params, t, p, c)

        chunk_j = jax.jit(chunk_fn)
        decode_j = jax.jit(decode_fn)
        reqs = _mk_reqs(4, cfg, [(9, 2), (6, 5), (2, 1), (11, 3), (3, 4)])
        stats = serve_continuous(
            None,
            lambda t, p, c: decode_j(params, t, p, c),
            lambda b: tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32),
            reqs, batch_slots=2,
            chunk_fn=lambda t, pm, m, c: chunk_j(params, t, pm, m, c),
            prefill_chunk=4)
        assert stats.chunk_steps >= 5            # several chunk rounds
        assert traces == {"chunk": 1, "decode": 1}


@pytest.mark.paged
class TestPagedChunked:
    def test_paged_chunked_matches_dense_unchunked(self, tiny):
        """Chunked serving over a pool-constrained paged cache == dense
        unchunked, with per-chunk block growth and no block leak."""
        cfg, params = tiny
        base = _mk_reqs(3, cfg, SPEC)
        _serve_real(cfg, params, base)
        reqs = _mk_reqs(3, cfg, SPEC)
        stats, pool = _serve_real(cfg, params, reqs, chunk=5, paged=True,
                                  num_blocks=10)
        for b, r in zip(base, reqs):
            assert b.tokens_out == r.tokens_out, r.rid
        assert stats.chunk_steps > 0
        assert pool.blocks_in_use == 0           # every block freed
        assert pool.blocks_reserved == 0

    def test_first_chunk_maps_only_its_own_blocks(self, tiny):
        """Chunked admission maps ceil(first_chunk/bs) blocks, not the
        whole prompt's — the O(chunk/block_size) growth contract."""
        cfg, params = tiny
        admit, chunkstep, decode, prefill = _steps(cfg)
        nb_lane = blocks_for_tokens(MAX_LEN, 8)
        pool = BlockPool(8, 8, 1, nb_lane)
        from repro.runtime.serve_loop import Scheduler
        sched = Scheduler(
            None,
            lambda t, p, c: decode(params, t, p, c),
            lambda b: tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                                     paged=True, block_size=8, num_blocks=8,
                                     mapped=False),
            batch_slots=1, max_len=MAX_LEN, block_pool=pool,
            chunk_fn=lambda t, pm, m, c: chunkstep(params, t, pm, m, c),
            prefill_chunk=8)
        seen = []
        orig_grow = pool.grow

        def spy_grow(lane, n_total):
            orig_grow(lane, n_total)
            seen.append(pool.blocks_in_use)
        pool.grow = spy_grow
        reqs = _mk_reqs(9, cfg, [(24, 2)])       # 3 chunks of 8
        sched.run(reqs)
        # admission maps the FIRST chunk's single block; each later chunk's
        # grow adds exactly one more (chunk 1's grow is a no-op)
        assert seen[:3] == [1, 2, 3]
        assert reqs[0].done


@pytest.mark.deploy
class TestDeployChunked:
    """Chunked parity on the integer deployment path: the calibrated int8
    KV cache round-trips storage exactly, so reading earlier chunks back
    from the cache matches the monolithic fresh-K/V prefill."""

    @pytest.fixture(scope="class")
    def deployed(self):
        from repro.core import Mode, QuantCtx, build_deploy, peg_policy
        from repro.core.pipeline import ptq
        cfg = get_config("gemma2-2b").reduced()
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
        pol = peg_policy(4)
        flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
        calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10),
                                               (2, 8), 0, cfg.vocab_size)}]

        def fwd(p, b, ctx):
            logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
            return logits

        qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
        shared = {}
        for site, qp in qm.act_state.items():
            base = ("layer/" + site.split("/", 1)[1]
                    if site.startswith("layer") else site)
            shared.setdefault(base, qp)
        packed, acts = build_deploy(cfg, params, pol, shared)

        def ctx_factory():
            return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                            deploy_acts=acts)
        return cfg, packed, ctx_factory

    @pytest.mark.parametrize("kv_bits", [16, 8])
    def test_chunked_matches_unchunked_int8(self, deployed, kv_bits):
        cfg, packed, ctx_factory = deployed
        spec = [(4, 2), (20, 6), (3, 1), (6, 4)]
        base = _mk_reqs(5, cfg, spec)
        _serve_real(cfg, packed, base, kv_bits=kv_bits,
                    ctx_factory=ctx_factory)
        reqs = _mk_reqs(5, cfg, spec)
        stats, _ = _serve_real(cfg, packed, reqs, kv_bits=kv_bits, chunk=6,
                               ctx_factory=ctx_factory)
        for b, r in zip(base, reqs):
            assert b.tokens_out == r.tokens_out, (kv_bits, r.rid)
        assert stats.chunk_steps > 0
