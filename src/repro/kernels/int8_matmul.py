"""Pallas TPU kernels: s8 x s8 -> s32 matmul with fused PEG re-scaling and a
fused deployment epilogue.

Realizes the paper's eq. (3)->(5) on the MXU. Two kernels:

  * per-tensor (eq. 3): int32 accumulation over the K grid, one re-scale at
    the end. Asymmetric activations are handled with the standard fixed-point
    zero-point correction  out = s_a s_w (A_q @ W_q - z_a * colsum(W_q)).
  * PEG (eq. 4->5): with per-embedding-group activation scales the
    accumulator is re-scaled once per GROUP. We align the K-grid to the PEG
    group boundaries, so each k-step contributes  s_g * (A_g @ W_g - z_g *
    colsum(W_g))  into an f32 VMEM scratch accumulator — exactly K
    re-scalings per output tile, fused with the matmul (no extra HBM
    traffic).

Both kernels share a fused EPILOGUE executed on the last k-step while the
accumulator tile is still in VMEM:

    f  = dequantized accumulator                       (f32, in VMEM)
    f += bias                   (optional)
    f  = activation(f)          (optional: gelu / silu / relu)
    f *= mul                    (optional f32 operand — the GLU gating path)
    o  = requantize(f)          (optional: emit int8 for the next matmul)

With the requantizing epilogue the FFN chain  LN -> quant -> W_in matmul ->
GELU -> requant -> W_out matmul  keeps int8 in HBM end-to-end: the f32
intermediate never leaves VMEM.

All scales / zero-points are TRACED operands (not compile-time constants), so
freshly calibrated scales never trigger a recompile and per-layer scales can
ride through a lax.scan over stacked layer weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Epilogue activations: the model-side table plus identity, shared so the
# DEPLOY epilogue can never diverge from the simulate-path activations.
from repro.models.common import ACTIVATIONS as _MODEL_ACTS
from repro.kernels.nibble import unpack_rows as _unpack_rows

EPILOGUE_ACTS = {"none": lambda x: x, **_MODEL_ACTS}


def _vmem_scratch(shape, dtype):
    """VMEM scratch accumulator (TPU target; interpret mode emulates it)."""
    return pltpu.VMEM(shape, dtype)


def _epilogue(f, refs, *, activation: str, has_bias: bool, has_mul: bool,
              requant: bool, qmin: int, qmax: int, o_ref):
    """Shared fused epilogue. ``f``: f32 (bm, bn) dequantized accumulator.
    ``refs``: dict of the optional operand refs present for this call."""
    if has_bias:
        f = f + refs["bias"][0, :][None, :]
    f = EPILOGUE_ACTS[activation](f)
    if has_mul:
        f = f * refs["mul"][...]
    if requant:
        s_out = refs["outq"][0]
        z_out = refs["outq"][1]
        q = jnp.clip(jnp.round(f / s_out) + z_out, qmin, qmax)
        o_ref[...] = q.astype(o_ref.dtype)
    else:
        o_ref[...] = f.astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Per-tensor path (paper eq. 3) + fused epilogue
# ---------------------------------------------------------------------------

def _int8_matmul_kernel(s_ref, za_ref, *rest, n_k: int, activation: str,
                        has_zp: bool, has_bias: bool, has_mul: bool,
                        requant: bool, qmin: int, qmax: int,
                        w_bits: int = 8):
    refs = {}
    rest = list(rest)
    if has_zp:
        refs["colsum"] = rest.pop(0)
    if has_bias:
        refs["bias"] = rest.pop(0)
    if has_mul:
        refs["mul"] = rest.pop(0)
    if requant:
        refs["outq"] = rest.pop(0)
    a_ref, w_ref, o_ref, acc_ref = rest

    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]
    if w_bits == 4:
        # unpack-to-int8 prologue: (bk/2, bn) row-packed nibbles -> (bk, bn)
        # in VMEM, so the MXU path below is byte-identical to the 8-bit one
        # while the HBM weight read halves.
        w = _unpack_rows(w)
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _done():
        acc = acc_ref[...].astype(jnp.float32)
        if has_zp:
            corr = refs["colsum"][0, :].astype(jnp.float32)
            acc = acc - za_ref[0] * corr[None, :]
        f = acc * s_ref[0]
        _epilogue(f, refs, activation=activation, has_bias=has_bias,
                  has_mul=has_mul, requant=requant, qmin=qmin, qmax=qmax,
                  o_ref=o_ref)


def int8_matmul(a_q: jnp.ndarray, w_q: jnp.ndarray, s_a, s_w, *,
                z_a=None, w_colsum: jnp.ndarray = None,
                bias: jnp.ndarray = None, mul: jnp.ndarray = None,
                activation: str = "none",
                out_scale=None, out_zp=None,
                qmin: int = -128, qmax: int = 127,
                out_dtype=jnp.float32, block_m: int = 256,
                block_n: int = 256, block_k: int = 512,
                w_bits: int = 8, interpret: bool = False) -> jnp.ndarray:
    """Per-tensor path (paper eq. 3) with fused epilogue.

    a_q: (M, K) int8, w_q: (K, N) int8; s_a/s_w traced scalars.
    z_a + w_colsum (N,): asymmetric-activation zero-point correction
    (for w_bits=4 the colsum must come from the UNPACKED int4 values).
    bias (N,), mul (M, N) f32, activation, out_scale/out_zp: the epilogue.
    When out_scale is given the output is int8 on the [qmin, qmax] grid.
    ``w_bits=4``: w_q is (K/2, N) pairwise-row-packed nibbles
    (repro.kernels.nibble.pack_rows); a packed k-block [a, b) is exactly
    original rows [2a, 2b), so the K grid walks packed rows directly.
    """
    m, k = a_q.shape
    _, n = w_q.shape
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    if w_bits == 4:
        assert bk % 2 == 0, f"w_bits=4 needs even block_k, got {bk}"
        assert w_q.shape[0] == k // 2, (
            f"packed w rows {w_q.shape[0]} != K/2 = {k // 2}")

    has_zp = w_colsum is not None
    has_bias = bias is not None
    has_mul = mul is not None
    requant = out_scale is not None
    if requant:
        out_dtype = jnp.int8

    s_prod = (jnp.asarray(s_a, jnp.float32) *
              jnp.asarray(s_w, jnp.float32)).reshape(1)
    za = jnp.asarray(0.0 if z_a is None else z_a, jnp.float32).reshape(1)

    operands = [s_prod, za]
    in_specs = [pl.BlockSpec((1,), lambda i, j, kk: (0,)),
                pl.BlockSpec((1,), lambda i, j, kk: (0,))]
    if has_zp:
        operands.append(w_colsum.reshape(1, n))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if has_bias:
        operands.append(bias.astype(jnp.float32).reshape(1, n))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if has_mul:
        operands.append(mul.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
    if requant:
        outq = jnp.stack([jnp.asarray(out_scale, jnp.float32).reshape(()),
                          jnp.asarray(0.0 if out_zp is None else out_zp,
                                      jnp.float32).reshape(())])
        operands.append(outq)
        in_specs.append(pl.BlockSpec((2,), lambda i, j, kk: (0,)))
    bkw = bk // 2 if w_bits == 4 else bk
    operands += [a_q, w_q]
    in_specs += [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                 pl.BlockSpec((bkw, bn), lambda i, j, kk: (kk, j))]

    kernel = functools.partial(
        _int8_matmul_kernel, n_k=k // bk, activation=activation,
        has_zp=has_zp, has_bias=has_bias, has_mul=has_mul, requant=requant,
        qmin=qmin, qmax=qmax, w_bits=w_bits)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        grid=(m // bm, n // bn, k // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.int32)],
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# PEG path (paper eq. 4->5) + fused epilogue
# ---------------------------------------------------------------------------

def _int8_matmul_peg_kernel(sw_ref, sa_ref, za_ref, wcs_ref, *rest,
                            n_k: int, activation: str, has_bias: bool,
                            has_mul: bool, requant: bool, qmin: int,
                            qmax: int, w_bits: int = 8):
    refs = {}
    rest = list(rest)
    if has_bias:
        refs["bias"] = rest.pop(0)
    if has_mul:
        refs["mul"] = rest.pop(0)
    if requant:
        refs["outq"] = rest.pop(0)
    a_ref, w_ref, o_ref, acc_ref = rest

    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...]
    if w_bits == 4:
        # unpack-to-int8 prologue (see _int8_matmul_kernel); PEG group
        # boundaries stay row-aligned because the group size is even.
        w = _unpack_rows(w)
    part = jax.lax.dot_general(a_ref[...], w,
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)
    s_g = sa_ref[0]
    z_g = za_ref[0]
    # zero-point correction: z_g * colsum(W_q,g), precomputed per (group, n)
    corr = wcs_ref[0, :].astype(jnp.float32)
    acc_ref[...] += s_g * (part.astype(jnp.float32) - z_g * corr[None, :])

    @pl.when(k_idx == n_k - 1)
    def _done():
        f = acc_ref[...] * sw_ref[0]
        _epilogue(f, refs, activation=activation, has_bias=has_bias,
                  has_mul=has_mul, requant=requant, qmin=qmin, qmax=qmax,
                  o_ref=o_ref)


def int8_matmul_peg(a_q: jnp.ndarray, w_q: jnp.ndarray,
                    act_scales: jnp.ndarray, act_zps: jnp.ndarray,
                    w_scale, w_colsum_g: jnp.ndarray, *,
                    bias: jnp.ndarray = None, mul: jnp.ndarray = None,
                    activation: str = "none",
                    out_scale=None, out_zp=None,
                    qmin: int = -128, qmax: int = 127,
                    out_dtype=jnp.float32, block_m: int = 256,
                    block_n: int = 256, w_bits: int = 8,
                    interpret: bool = False) -> jnp.ndarray:
    """a_q: (M, K) int8 group-sorted; w_q: (K, N) int8; act_scales/zps: (G,);
    w_colsum_g: (G, N) int32 = per-group column sums of w_q (always from the
    UNPACKED values); w_scale traced scalar. K % G == 0 and group_size =
    K // G (the k-block). ``w_bits=4``: w_q is (K/2, N) row-packed nibbles;
    needs an even group size so group boundaries stay byte-aligned.
    Epilogue args as in :func:`int8_matmul`."""
    m, k = a_q.shape
    k2, n = w_q.shape
    assert k == (2 * k2 if w_bits == 4 else k2)
    g = act_scales.shape[0]
    assert k % g == 0
    bk = k // g
    if w_bits == 4:
        assert bk % 2 == 0, f"w_bits=4 needs even PEG group size, got {bk}"
    bm, bn = min(block_m, m), min(block_n, n)
    assert m % bm == 0 and n % bn == 0

    has_bias = bias is not None
    has_mul = mul is not None
    requant = out_scale is not None
    if requant:
        out_dtype = jnp.int8

    operands = [jnp.asarray(w_scale, jnp.float32).reshape(1),
                act_scales.astype(jnp.float32),
                act_zps.astype(jnp.float32),
                w_colsum_g]
    in_specs = [pl.BlockSpec((1,), lambda i, j, kk: (0,)),       # s_w
                pl.BlockSpec((1,), lambda i, j, kk: (kk,)),      # s_g
                pl.BlockSpec((1,), lambda i, j, kk: (kk,)),      # z_g
                pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j))]  # colsum
    if has_bias:
        operands.append(bias.astype(jnp.float32).reshape(1, n))
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
    if has_mul:
        operands.append(mul.astype(jnp.float32))
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
    if requant:
        outq = jnp.stack([jnp.asarray(out_scale, jnp.float32).reshape(()),
                          jnp.asarray(0.0 if out_zp is None else out_zp,
                                      jnp.float32).reshape(())])
        operands.append(outq)
        in_specs.append(pl.BlockSpec((2,), lambda i, j, kk: (0,)))
    bkw = bk // 2 if w_bits == 4 else bk
    operands += [a_q, w_q]
    in_specs += [pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
                 pl.BlockSpec((bkw, bn), lambda i, j, kk: (kk, j))]

    kernel = functools.partial(
        _int8_matmul_peg_kernel, n_k=g, activation=activation,
        has_bias=has_bias, has_mul=has_mul, requant=requant,
        qmin=qmin, qmax=qmax, w_bits=w_bits)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        grid=(m // bm, n // bn, g),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        scratch_shapes=[_vmem_scratch((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
