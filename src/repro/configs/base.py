"""Architecture config schema + registry.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``repro/configs/<id>.py``), selectable via ``--arch <id>`` in the launchers.
``reduced()`` produces the small same-family variant used by CPU smoke tests;
``input_specs(shape)`` produces ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.moe import MoEConfig

# The four assigned input shapes (LM-family): (seq_len, global_batch).
SHAPES: Dict[str, dict] = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention variants
    window: Optional[int] = None             # sliding-window size (all layers)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    rope_theta: Optional[float] = 10000.0
    norm: str = "rmsnorm"                    # rmsnorm | layernorm
    act: str = "silu"
    ffn_type: str = "glu"                    # glu | mlp
    post_norm: bool = False                  # gemma-2 sandwich norms
    qk_norm: bool = False                    # qwen3
    embed_scale: bool = False                # gemma: x *= sqrt(d)
    tie_embeddings: bool = True
    # block pattern, repeated; tail appended at the end.
    # entries: "attn" | "local_attn" | "rec" | "rwkv"
    block_pattern: Tuple[str, ...] = ("attn",)
    tail_pattern: Tuple[str, ...] = ()
    local_window: int = 4096                 # window for "local_attn" blocks
    d_rnn: Optional[int] = None              # RG-LRU width
    rwkv_head_size: int = 64
    # MoE
    moe: Optional[MoEConfig] = None
    # encoder-decoder (seamless): encoder_layers > 0
    encoder_layers: int = 0
    # modality frontend stub
    frontend: Optional[str] = None           # audio | vision
    num_frontend_tokens: int = 0
    max_seq_len: int = 1 << 20
    sub_quadratic: bool = False              # eligible for long_500k
    skip_decode: bool = False                # encoder-only archs
    # source provenance (from the assignment table)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def layer_plan(self) -> Tuple[str, ...]:
        """Full per-layer block-type sequence of length num_layers."""
        n = self.num_layers - len(self.tail_pattern)
        reps, rem = divmod(n, len(self.block_pattern))
        if rem:
            raise ValueError(f"{self.name}: {n} layers not divisible by "
                             f"pattern {self.block_pattern}")
        return self.block_pattern * reps + self.tail_pattern

    @property
    def num_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        n = self.vocab_size * d                      # embeddings
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for kind in self.layer_plan:
            if kind in ("attn", "local_attn"):
                n += d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                    + self.num_heads * hd * d
                if self.moe is not None:
                    n += d * self.moe.num_experts + self.moe.num_experts * \
                        3 * d * self.moe.d_ff
                elif self.ffn_type == "glu":
                    n += 3 * d * self.d_ff
                else:
                    n += 2 * d * self.d_ff
            elif kind == "rec":
                dr = self.d_rnn or d
                n += 2 * d * dr + dr * d + 2 * dr * dr
                n += 3 * d * self.d_ff if self.ffn_type == "glu" else 2 * d * self.d_ff
            elif kind == "rwkv":
                n += 5 * d * d + 2 * d * self.d_ff + d * d
        if self.encoder_layers:
            # encoder layers + decoder cross-attention
            n += self.encoder_layers * (4 * d * d + 2 * d * self.d_ff)
            n += self.num_layers * 4 * d * d
        return n

    def active_params(self) -> int:
        """Activated parameters per token (MoE: top-k of the experts)."""
        if self.moe is None:
            return self.num_params
        d = self.d_model
        total = self.num_params
        expert_p = self.moe.num_experts * 3 * d * self.moe.d_ff
        active_p = self.moe.top_k * 3 * d * self.moe.d_ff
        return total - len(self.layer_plan) * expert_p \
            + len(self.layer_plan) * active_p

    def with_supers(self, n_super: int) -> "ModelConfig":
        """Same config with ``n_super`` block-pattern repeats (+ tail) — used
        by the dry-run's cost-extrapolation lowerings (scan bodies are
        counted once by XLA cost analysis; we lower at 1 and 2 repeats and
        extrapolate linearly in n_super)."""
        n_layers = n_super * len(self.block_pattern) + len(self.tail_pattern)
        return dataclasses.replace(
            self, num_layers=n_layers,
            encoder_layers=n_super if self.encoder_layers else 0)

    @property
    def n_super(self) -> int:
        return (self.num_layers - len(self.tail_pattern)) // \
            len(self.block_pattern)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        pat = len(self.block_pattern)
        tail = len(self.tail_pattern)
        moe = None
        if self.moe is not None:
            # capacity_factor 4.0: an untrained router's skew must not drop
            # tokens in smoke tests (drops are legitimate at scale, but make
            # decode-vs-dense consistency checks flaky).
            moe = dataclasses.replace(self.moe, num_experts=4,
                                      top_k=min(self.moe.top_k, 2), d_ff=64,
                                      capacity_factor=4.0)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=2 * pat + tail,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=16,
            d_ff=128,
            vocab_size=128,
            d_rnn=64 if self.d_rnn else None,
            rwkv_head_size=16,
            window=min(self.window, 16) if self.window else None,
            local_window=16,
            encoder_layers=2 if self.encoder_layers else 0,
            num_frontend_tokens=8 if self.frontend else 0,
            max_seq_len=256,
            moe=moe,
        )


def input_specs(cfg: ModelConfig, shape_name: str, *,
                microbatch: Optional[int] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    train: {tokens, labels [, embeds]}   (B, T) int32
    prefill: {tokens [, embeds]}
    decode: {tokens (B, 1), pos (B, 1)} — the KV cache / state is built
      separately by the launcher (init fns) because its layout is
      arch-specific.
    """
    sh = SHAPES[shape_name]
    B, T = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    i32 = jnp.int32
    if kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((B, T), i32),
                 "labels": jax.ShapeDtypeStruct((B, T), i32)}
    elif kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((B, T), i32)}
    else:  # decode: one new token against a T-long cache
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                 "pos": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.frontend and kind != "decode":
        specs["embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    return specs


_REGISTRY = [
    "h2o_danube3_4b", "internlm2_20b", "gemma2_2b", "granite_20b",
    "qwen3_moe_235b", "grok1_314b", "recurrentgemma_2b", "rwkv6_1p6b",
    "seamless_m4t_medium", "phi3_vision_4p2b", "bert_base",
]

# --arch ids use dashes; module names use underscores.
ARCH_IDS = [m.replace("_", "-") for m in _REGISTRY]


def get_config(arch_id: str) -> ModelConfig:
    mod_name = arch_id.replace("-", "_")
    if mod_name not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_cells(cfg: ModelConfig):
    """The shape names this arch runs (with assignment-mandated skips)."""
    cells = []
    for name, sh in SHAPES.items():
        if sh["kind"] == "decode" and cfg.skip_decode:
            continue
        if name == "long_500k" and not cfg.sub_quadratic:
            continue
        cells.append(name)
    return cells
