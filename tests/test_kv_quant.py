"""Int8 KV-cache serving path: the fused decode kernel must match (a) its
pure-jnp oracle, (b) the bf16/f32-cache attention it replaces, across global
/ sliding-window / GQA / softcap variants — plus round-trip properties of
the per-head k/v quantizer (hypothesis, matching test_properties.py idiom).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models import attention as att

pytestmark = pytest.mark.deploy


def _rand_cache_operands(key, B=2, S=40, KV=2, G=2, hd=16, valid=37):
    ks = jax.random.split(key, 7)
    q_q = jax.random.randint(ks[0], (B, KV, G, hd), -128, 128, jnp.int8)
    qs = jax.random.uniform(ks[1], (B, KV, G), minval=0.01, maxval=0.05)
    qz = jnp.round(jax.random.uniform(ks[6], (B, KV, G), minval=-20.0,
                                      maxval=20.0))
    k_q = jax.random.randint(ks[2], (B, S, KV, hd), -127, 128, jnp.int8)
    k_s = jax.random.uniform(ks[3], (B, S, KV), minval=0.01, maxval=0.05)
    v_q = jax.random.randint(ks[4], (B, S, KV, hd), -127, 128, jnp.int8)
    v_s = jax.random.uniform(ks[5], (B, S, KV), minval=0.01, maxval=0.05)
    k_pos = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    k_pos = k_pos.at[:, valid:].set(-1)           # empty ring slots
    q_pos = jnp.full((B,), valid - 1, jnp.int32)
    return q_q, qs, qz, k_q, k_s, v_q, v_s, k_pos, q_pos


class TestKernelVsOracle:
    @pytest.mark.parametrize("window,softcap", [
        (None, None), (16, None), (None, 50.0), (8, 30.0)])
    def test_matches_ref(self, window, softcap):
        (q_q, qs, qz, k_q, k_s, v_q, v_s, k_pos,
         q_pos) = _rand_cache_operands(jax.random.PRNGKey(0))
        got = ops.int8_attend_decode(q_q, qs, k_q, k_s, v_q, v_s, k_pos,
                                     q_pos, q_zp=qz, window=window,
                                     logit_softcap=softcap, chunk=16)
        want = ref.int8_attend_decode_ref(q_q, qs, k_q, k_s, v_q, v_s,
                                          k_pos, q_pos, q_zp=qz,
                                          window=window,
                                          logit_softcap=softcap)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)

    def test_softmax_sites_in_kernel(self):
        """softmax_in (one-pass) and softmax_out (two-pass schedule) both
        match the oracle's fake-quant placement exactly."""
        (q_q, qs, qz, k_q, k_s, v_q, v_s, k_pos,
         q_pos) = _rand_cache_operands(jax.random.PRNGKey(1))
        smq = jnp.asarray([0.02, 100.0])
        smo = jnp.asarray([1.0 / 255.0, 0.0])
        got = ops.int8_attend_decode(q_q, qs, k_q, k_s, v_q, v_s, k_pos,
                                     q_pos, q_zp=qz, logit_softcap=50.0,
                                     sm_quant=smq, smo_quant=smo, chunk=16)
        want = ref.int8_attend_decode_ref(q_q, qs, k_q, k_s, v_q, v_s,
                                          k_pos, q_pos, q_zp=qz,
                                          logit_softcap=50.0, sm_quant=smq,
                                          smo_quant=smo)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)

    def test_ragged_s_padding(self):
        """S not a multiple of the chunk pads with empty slots (ops layer)."""
        (q_q, qs, qz, k_q, k_s, v_q, v_s, k_pos,
         q_pos) = _rand_cache_operands(jax.random.PRNGKey(2), S=21, valid=21)
        got = ops.int8_attend_decode(q_q, qs, k_q, k_s, v_q, v_s, k_pos,
                                     q_pos, q_zp=qz, chunk=8)
        want = ref.int8_attend_decode_ref(q_q, qs, k_q, k_s, v_q, v_s,
                                          k_pos, q_pos, q_zp=qz)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-5, atol=3e-5)


class TestDecodeParity:
    """Quantized cache vs f32 cache through the full attention block."""

    @pytest.mark.parametrize("window,softcap,KV", [
        (None, None, 4),     # MHA global
        (None, 50.0, 2),     # GQA + softcap
        (16, 50.0, 2),       # sliding-window ring buffer
        (4, None, 1),        # MQA, window wraps several times
    ])
    def test_block_decode_parity(self, window, softcap, KV):
        cfg = att.AttnConfig(num_heads=4, num_kv_heads=KV, head_dim=16,
                             window=window, logit_softcap=softcap)
        B, D, max_len = 2, 64, 32
        p = att.init_attention_params(jax.random.PRNGKey(0), D, cfg,
                                      jnp.float32)
        c16 = att.init_kv_cache(B, max_len, cfg, jnp.float32)
        c8 = att.init_quant_kv_cache(B, max_len, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, 5, D)) * 0.5
        pos = jnp.broadcast_to(jnp.arange(5), (B, 5)).astype(jnp.int32)
        o16, c16 = att.attention_block(p, x, pos, cfg, cache=c16)
        o8, c8 = att.attention_block(p, x, pos, cfg, cache=c8)
        # prefill attends over the fresh f32 K/V in both cases
        np.testing.assert_allclose(np.asarray(o16), np.asarray(o8),
                                   rtol=1e-5, atol=1e-5)
        for t in range(5, 11):                     # wraps the W=4 ring
            xt = jax.random.normal(jax.random.PRNGKey(10 + t),
                                   (B, 1, D)) * 0.5
            pt = jnp.full((B, 1), t, jnp.int32)
            o16, c16 = att.attention_block(p, xt, pt, cfg, cache=c16)
            o8, c8 = att.attention_block(p, xt, pt, cfg, cache=c8)
            rel = float(jnp.max(jnp.abs(o16 - o8)) /
                        (jnp.max(jnp.abs(o16)) + 1e-9))
            assert rel < 0.03, (t, rel)

    def test_decode_matches_dequantized_flash(self):
        """The kernel path equals attending over the dequantized cache (the
        fallback path) up to the query's int8 rounding."""
        cfg = att.AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16)
        B, D = 2, 64
        p = att.init_attention_params(jax.random.PRNGKey(3), D, cfg,
                                      jnp.float32)
        c8 = att.init_quant_kv_cache(B, 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(4), (B, 4, D)) * 0.5
        pos = jnp.broadcast_to(jnp.arange(4), (B, 4)).astype(jnp.int32)
        _, c8 = att.attention_block(p, x, pos, cfg, cache=c8)
        xt = jax.random.normal(jax.random.PRNGKey(5), (B, 1, D)) * 0.5
        pt = jnp.full((B, 1), 4, jnp.int32)
        out, c8b = att.attention_block(p, xt, pt, cfg, cache=c8)
        # rebuild the same attend on the dequantized cache
        kf, vf = att.dequantize_kv(c8b)
        # recompute q exactly like the block does
        from repro.models.common import apply_rope
        q = (xt @ p["wq"]).reshape(B, 1, 4, 16)
        q = apply_rope(q, pt, cfg.rope_theta)
        o_ref = att.attend(q, kf.astype(q.dtype), vf.astype(q.dtype),
                           pt, c8b.pos, cfg)
        o_ref2d = o_ref.reshape(B, 1, 64)
        want = o_ref2d @ p["wo"]
        rel = float(jnp.max(jnp.abs(out - want)) /
                    (jnp.max(jnp.abs(want)) + 1e-9))
        assert rel < 0.02, rel


class TestKVQuantFor:
    def test_peg_calibrated_site_falls_back(self):
        """PEG group scales partition a permuted channel axis, not the
        (KV, hd) head layout — kv_quant_for must return None (the cache
        then quantizes dynamically) instead of mis-mapping group scales
        onto heads."""
        from repro.core import deploy, peg_policy
        from repro.core.quantizer import QuantParams
        pol = peg_policy(4, ffn_only=False)       # PEG covers the k/v sites
        state = {}
        for name in ("k", "v"):
            state[f"layer/attn/{name}"] = QuantParams(
                scale=jnp.asarray([1e-3, 1e-2, 1e-1, 1.0]),
                zero_point=jnp.zeros((4,)),
                group_index=jnp.arange(32) % 4)
        assert deploy.kv_quant_for(state, pol, "layer/attn", 2) is None

    def test_per_tensor_site_builds_grids(self):
        from repro.core import deploy, w8a8_policy
        from repro.core.quantizer import QuantParams
        state = {f"layer/attn/{n}": QuantParams(
            scale=jnp.asarray(0.02), zero_point=jnp.asarray(140.0))
            for n in ("k", "v")}
        kvq = deploy.kv_quant_for(state, w8a8_policy(), "layer/attn", 2)
        assert kvq is not None
        np.testing.assert_allclose(np.asarray(kvq.k_grid), [0.02, 0.02])
        np.testing.assert_allclose(np.asarray(kvq.k_zp), [12.0, 12.0])


class TestQuantizeKV:
    def test_dynamic_symmetric(self):
        x = jnp.asarray([0.5, -3.0, 10.0, 0.01]).reshape(1, 1, 1, 4)
        q, s = att.quantize_kv(x)
        assert q.dtype == jnp.int8
        np.testing.assert_allclose(np.asarray(s), 10.0 / 127.0, rtol=1e-6)
        assert int(q[0, 0, 0, 2]) == 127
        # grid floor: scale snaps up to the site grid step
        q2, s2 = att.quantize_kv(x, grid_scale=jnp.asarray([0.2]))
        np.testing.assert_allclose(np.asarray(s2), 0.2, rtol=1e-6)

    def test_affine_site_grid_roundtrip_exact(self):
        """Values already fake-quantized on the calibrated (asymmetric) site
        grid round-trip the cache EXACTLY — the deploy parity mechanism.
        The zero-point stays out of the per-slot payload."""
        grid, zp = 0.03, 12.0         # shifted zp: site levels [-140, 115]
        ints = jax.random.randint(jax.random.PRNGKey(0), (2, 7, 2, 16),
                                  -140, 116)
        x = ints.astype(jnp.float32) * grid
        q, s = att.quantize_kv(x, grid_scale=jnp.asarray([grid] * 2),
                               zero_point=jnp.asarray([zp] * 2))
        back = (q.astype(jnp.float32) - zp) * s[..., None]
        np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                                   rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Property-based round-trip (hypothesis, optional like test_properties.py —
# guarded so the kernel/parity tests above still run without it)
# ---------------------------------------------------------------------------

try:
    import hypothesis
    import hypothesis.extra.numpy as hnp
    import hypothesis.strategies as st
    from hypothesis import given
    _HAVE_HYPOTHESIS = True
except ImportError:                # pragma: no cover - dev-only dependency
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    hypothesis.settings.register_profile(
        "ci-kv", deadline=None, max_examples=25,
        suppress_health_check=[hypothesis.HealthCheck.too_slow])
    hypothesis.settings.load_profile("ci-kv")

    kv_arrays = hnp.arrays(
        np.float32, st.tuples(st.integers(1, 3), st.integers(1, 5),
                              st.integers(1, 3), st.integers(2, 16)),
        elements=st.floats(-1e3, 1e3, width=32))

    @given(kv_arrays)
    def test_kv_roundtrip_error_bounded(x):
        """|x - deq(q(x))| <= scale/2 per element without clipping."""
        q, s = att.quantize_kv(jnp.asarray(x))
        back = np.asarray(q.astype(jnp.float32) * s[..., None])
        err = np.abs(x - back)
        bound = np.asarray(s)[..., None] * 0.5 + 1e-6
        assert (err <= bound + 1e-3 * np.abs(x)).all()

    @given(kv_arrays, st.floats(1e-3, 1.0), st.floats(-30.0, 30.0))
    def test_kv_affine_grid_error_bounded(x, grid, zp):
        """Affine site-grid writes stay on the int8 grid and the round-trip
        error is bounded by grid/2 for values inside the representable
        range (clipped values saturate toward the range edge)."""
        zp = float(np.round(zp))
        q, s = att.quantize_kv(jnp.asarray(x), grid_scale=jnp.float32(grid),
                               zero_point=jnp.float32(zp))
        qn = np.asarray(q, np.int32)
        assert qn.min() >= -128 and qn.max() <= 127
        back = (qn.astype(np.float32) - zp) * np.asarray(s)[..., None]
        lo, hi = (-128 - zp) * grid, (127 - zp) * grid
        inside = (x >= lo) & (x <= hi)
        err = np.abs(x - back)
        assert (err[inside] <= grid * 0.5 + 1e-4 * np.abs(x[inside])
                + 1e-6).all()
else:                              # keep the skip visible in test reports
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(see requirements-dev.txt)")
    def test_kv_roundtrip_error_bounded():
        pass
