"""Shared model building blocks (pure JAX, functional)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def layer_norm(x, gamma, beta, eps: float = 1e-6):
    mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    var = jnp.var(x.astype(jnp.float32), axis=-1, keepdims=True)
    out = (x.astype(jnp.float32) - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma + beta).astype(x.dtype)


def rms_norm(x, gamma, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def softcap(x, cap: Optional[float]):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


ACTIVATIONS = {"gelu": gelu, "silu": silu, "relu": jax.nn.relu}


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., T, hd/2)
    angles = angles[..., None, :]                       # (..., T, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Token-level CE. logits (..., V), labels (...) int32. mask optional."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# int8 weight storage (W8 serving variant)
# ---------------------------------------------------------------------------

def resolve_weight(w):
    """Weights may be stored quantized: {"q": int8, "s": f32 per-out-channel}.
    Dequantization happens at the use site so XLA fuses the convert into the
    consuming matmul — HBM reads the int8 payload (2x fewer bytes than bf16,
    the serving win the paper targets)."""
    if isinstance(w, dict) and "q" in w:
        if "colsum" in w:
            # deploy-packed payload (repro.core.deploy): rows may be
            # PEG-permuted — dequantizing it here would silently compute
            # x @ (permuted W) with unpermuted x.
            raise TypeError(
                "deploy-packed weight reached a non-deploy path; packed "
                "payloads must be consumed via repro.core.deploy (Mode."
                "DEPLOY ctx) or unpacked before simulate-mode use")
        return (w["q"].astype(jnp.bfloat16) * w["s"].astype(jnp.bfloat16))
    return w


def quantize_weight_int8(w, axis: int = -1):
    """Symmetric per-out-channel int8 storage for a weight matrix."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=0, keepdims=True)
    s = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / s), -127, 127)
    return {"q": q.astype(jnp.int8), "s": s.astype(jnp.float32)}
