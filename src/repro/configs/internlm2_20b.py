"""internlm2-20b [dense] — GQA kv=8. [arXiv:2403.17297; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    rope_theta=1000000.0,
    norm="rmsnorm",
    act="silu",
    ffn_type="glu",
    tie_embeddings=False,
    sub_quadratic=False,          # pure full attention: skips long_500k
    source="arXiv:2403.17297; hf",
)
