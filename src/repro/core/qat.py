"""Quantization-aware training with learnable ranges (paper §4 "QAT").

Adapts LSQ (Esser et al. 2019) / trained uniform quantization (Jain et al.
2019) to BERT-like models: every quantizer's scale (and, for asymmetric
activations, offset) is a trainable parameter initialized from the PTQ
estimate, optimized jointly with the weights via the STE gradients that
``repro.core.quantizer.fake_quant`` already exposes.

Parameterization: scale is stored as log(s) for positivity; the asymmetric
zero-point is stored as a continuous offset (LSQ+-style) and rounded with STE
when used.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.quant_config import QuantizerConfig
from repro.core.quantizer import QuantParams, fake_quant, _round_ste


def init_qat_params(act_state: Dict[str, QuantParams],
                    weight_state: Dict[str, QuantParams]) -> dict:
    """Trainable pytree initialized from PTQ quantization parameters."""
    def to_learnable(qp: QuantParams):
        return {"log_scale": jnp.log(jnp.maximum(qp.scale, 1e-8)),
                "offset": qp.zero_point.astype(jnp.float32)}
    return {
        "act": {site: to_learnable(qp) for site, qp in act_state.items()},
        "weight": {site: to_learnable(qp) for site, qp in weight_state.items()},
    }


def _materialize(learnable: dict, template: QuantParams,
                 cfg: QuantizerConfig, grad_scale: jnp.ndarray) -> QuantParams:
    # LSQ gradient scaling: multiply the learnable leaf by g inside
    # stop_grad-compensated identity so the forward value is unchanged but the
    # gradient is scaled by g (Esser et al. 2019, eq. 5).
    def gscale(v):
        return v * grad_scale + jax.lax.stop_gradient(v * (1.0 - grad_scale))
    scale = jnp.exp(gscale(learnable["log_scale"]))
    if cfg.symmetric:
        zp = jnp.zeros_like(scale)
    else:
        zp = jnp.clip(_round_ste(gscale(learnable["offset"])), cfg.qmin, cfg.qmax)
    return QuantParams(scale=scale, zero_point=zp,
                       group_index=template.group_index)


def _lsq_grad_scale(x: jnp.ndarray, cfg: QuantizerConfig) -> jnp.ndarray:
    return jax.lax.rsqrt(jnp.asarray(x.size * max(cfg.qmax, 1), jnp.float32))


def apply_act(ctx, site: str, x: jnp.ndarray, cfg: QuantizerConfig):
    learnable = (ctx.qat_params or {}).get("act", {}).get(site)
    template = (ctx.act_state or {}).get(site)
    if learnable is None or template is None:
        return x
    qp = _materialize(learnable, template, cfg, _lsq_grad_scale(x, cfg))
    return fake_quant(x, qp, cfg)


def apply_weight(ctx, site: str, w: jnp.ndarray, cfg: QuantizerConfig):
    learnable = (ctx.qat_params or {}).get("weight", {}).get(site)
    template = (ctx.weight_state or {}).get(site)
    if learnable is None or template is None:
        # Weight sites not present in the PTQ state fall back to on-the-fly
        # min-max fake-quant so QAT still sees quantization noise everywhere.
        from repro.core.range_estimation import estimate_weight_params
        import dataclasses as _dc
        from repro.core.quant_config import RangeEstimator
        cheap = _dc.replace(cfg, estimator=RangeEstimator.CURRENT_MINMAX)
        return fake_quant(w, estimate_weight_params(w, cheap), cheap)
    qp = _materialize(learnable, template, cfg, _lsq_grad_scale(w, cfg))
    return fake_quant(w, qp, cfg)
