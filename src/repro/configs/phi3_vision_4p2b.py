"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP vision frontend (stub:
precomputed patch embeddings). [hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,              # MHA
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    rope_theta=10000.0,
    norm="rmsnorm",
    act="silu",
    ffn_type="glu",
    tie_embeddings=False,
    frontend="vision",
    num_frontend_tokens=576,      # 336px / 14 -> 24x24 CLIP patches
    sub_quadratic=False,
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
)
