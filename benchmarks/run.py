"""Benchmark orchestrator — one section per paper table plus the kernel
micro-bench and the roofline table from the dry-run artifacts.

Prints ``name,us_per_call,derived`` style CSV per section. Heavy sections
(model training, QAT) cache under benchmarks/results/ — a re-run with warm
caches completes in seconds.

  PYTHONPATH=src python -m benchmarks.run [--sections t1,t5,kernels,...]
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = ["t1", "t2", "t4", "t5", "t6", "t7", "kernels", "serving",
            "engine", "roofline"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sections", default=",".join(SECTIONS))
    args = ap.parse_args(argv)
    want = args.sections.split(",")

    def section(name, title, fn):
        if name not in want:
            return
        t0 = time.time()
        print(f"\n### {title}")
        try:
            print(fn())
        except FileNotFoundError as e:
            print(f"(skipped: missing artifact {e})")
        except Exception as e:
            import traceback
            traceback.print_exc()
            print(f"(section {name} FAILED: {e})")
        print(f"# section {name} took {time.time() - t0:.1f}s", flush=True)

    from benchmarks import (kernel_bench, roofline, serving_bench,
                            table1_ptq, table2_ablation,
                            table4_mixed_precision, table5_peg,
                            table6_methods, table7_lowbit)

    section("t1", "Table 1 — standard 8-bit PTQ (paper Table 1)",
            lambda: table1_ptq.report(table1_ptq.run()))
    section("t2", "Table 2 — leave-one-out activation ablation",
            lambda: table2_ablation.report(table2_ablation.run()))
    section("t4", "Table 4 — mixed-precision PTQ",
            lambda: table4_mixed_precision.report(
                table4_mixed_precision.run()))
    section("t5", "Table 5 — per-embedding-group PTQ (K sweep, ±P)",
            lambda: table5_peg.report(table5_peg.run()))
    section("t6", "Table 6 — method comparison incl. QAT",
            lambda: table6_methods.report(table6_methods.run()))
    section("t7", "Table 7 — low-bit weights & embeddings",
            lambda: table7_lowbit.report(table7_lowbit.run()))
    def _kernels():
        rows = kernel_bench.bench()
        path = kernel_bench.write_json(rows)
        return kernel_bench.report(rows) + f"\n# wrote {path}"

    section("kernels", "Pallas kernel micro-bench (interpret mode + "
            "TPU roofline)", _kernels)

    def _serving():
        rows = serving_bench.bench()
        path = serving_bench.write_json(rows)
        return serving_bench.report(rows) + f"\n# wrote {path}"

    section("serving", "Serving schedulers — static vs continuous "
            "batching on a skewed-quota workload", _serving)

    def _engine():
        from benchmarks import engine_bench
        rows = engine_bench.bench()
        path = engine_bench.write_json(rows)
        return engine_bench.report(rows) + f"\n# wrote {path}"

    section("engine", "Engine API — prefill/insert/generate per-call "
            "timings with parity asserted (incl. sharded decode)", _engine)
    section("roofline", "Roofline terms per dry-run cell "
            "(EXPERIMENTS.md §Roofline)", roofline.report)


if __name__ == "__main__":
    main()
