"""Mixed-precision utilities (paper §4 "Mixed precision PTQ", Table 4).

The policy construction itself lives in quant_config.mixed_precision_policy;
this module adds the accounting the paper reports (what fraction of activation
quantizers run at 16-bit — "36 out of 161 for BERT-base") and a sensitivity
sweep that reproduces the leave-one-out analysis of Table 2.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

from repro.core.quant_config import FP32, QuantizationPolicy


def quantizer_census(policy: QuantizationPolicy, sites: Sequence[str]
                     ) -> Dict[str, int]:
    """Histogram of activation bit-widths over the given sites."""
    hist: Dict[str, int] = {}
    for s in sites:
        cfg = policy.act_config(s)
        key = "fp32" if not cfg.enabled else f"a{cfg.bits}"
        hist[key] = hist.get(key, 0) + 1
    return hist


def leave_one_out_policies(base: QuantizationPolicy, site_patterns: Sequence[str]
                           ) -> List[Tuple[str, QuantizationPolicy]]:
    """Table-2-style ablations: for each pattern, a policy identical to
    ``base`` but with that activation group kept in FP32."""
    import dataclasses
    out = []
    for pat in site_patterns:
        overrides = dict(base.act_overrides)
        overrides[pat] = FP32
        out.append((pat, dataclasses.replace(base, act_overrides=overrides)))
    return out


def sensitivity_sweep(evaluate: Callable[[QuantizationPolicy], float],
                      base: QuantizationPolicy,
                      site_patterns: Sequence[str]) -> Dict[str, float]:
    """Run the evaluation callback for every leave-one-out policy. The
    pattern whose exclusion recovers the most metric is the bottleneck —
    the paper finds it to be ``.*residual_ffn``."""
    return {pat: evaluate(pol)
            for pat, pol in leave_one_out_policies(base, site_patterns)}
