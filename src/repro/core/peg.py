"""Per-embedding-group (PEG) quantization — the paper's novel scheme (§4).

Given per-embedding-dimension calibrated dynamic ranges r_j = max_j - min_j,
we build K evenly-sized groups. With ``use_permutation`` (the "+P" rows of
Table 5) groups follow ``argsort(r)`` so all outlier dims land in the same
group; without it, groups are contiguous chunks of the natural dim order.

TPU adaptation (DESIGN.md §3):
  * group boundaries are aligned to LANE=128 multiples so a group never
    straddles an MXU tile / VREG lane boundary;
  * the permutation is *folded into adjacent weights* (LayerNorm affine, W_in
    rows, W_out columns — permutation-equivariance, paper Fig. 4) so the
    runtime layout is already group-sorted and no gather is executed;
  * `split_linear_for_per_tensor_hw` implements the paper's Fig.-4 rewriting
    for targets with only per-tensor support, used as an equivalence oracle.

TP awareness: when the embedding axis is sharded ``model``-ways, group count
is chosen per shard (K_total = K_per_shard * tp) and the permutation is
restricted to permute *within* shards, so no cross-device data movement is
introduced by quantization.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import numpy as np
import jax.numpy as jnp

from repro.core.quant_config import QuantizerConfig

LANE = 128  # TPU VREG lane width / MXU tile edge


class PEGSpec(NamedTuple):
    """Static grouping decision for one activation site (host-side)."""
    permutation: np.ndarray        # (d,) dim order: position -> original dim
    inverse_permutation: np.ndarray
    group_index: np.ndarray        # (d,) group id *in permuted layout*
    num_groups: int
    group_sizes: np.ndarray        # (K,)


def _even_group_sizes(d: int, k: int, lane_align: bool) -> np.ndarray:
    """K near-even group sizes summing to d; multiples of LANE if possible."""
    if lane_align and d % LANE == 0 and (d // LANE) >= k:
        units = d // LANE
        base = units // k
        rem = units % k
        sizes = np.full(k, base, dtype=np.int64)
        sizes[:rem] += 1
        return sizes * LANE
    base = d // k
    rem = d % k
    sizes = np.full(k, base, dtype=np.int64)
    sizes[:rem] += 1
    return sizes


def build_groups(ranges: np.ndarray, num_groups: int, *,
                 use_permutation: bool = True,
                 lane_align: bool = True,
                 tp_shards: int = 1) -> PEGSpec:
    """Build the PEG spec from calibrated per-dim dynamic ranges.

    ranges: (d,) non-negative per-embedding-dim dynamic range (max - min).
    tp_shards: if >1, dims are partitioned into `tp_shards` contiguous shards
      and the permutation only reorders within each shard; num_groups must be
      divisible by tp_shards (K_per_shard groups each).
    """
    ranges = np.asarray(ranges, dtype=np.float64)
    d = ranges.shape[0]
    if num_groups < 1 or num_groups > d:
        raise ValueError(f"num_groups={num_groups} out of range for d={d}")
    if d % tp_shards != 0:
        raise ValueError(f"d={d} not divisible by tp_shards={tp_shards}")
    if num_groups % tp_shards != 0:
        raise ValueError(f"num_groups={num_groups} not divisible by "
                         f"tp_shards={tp_shards}")

    if tp_shards > 1:
        per = d // tp_shards
        k_per = num_groups // tp_shards
        perms, gidx, sizes = [], [], []
        for s in range(tp_shards):
            sub = build_groups(ranges[s * per:(s + 1) * per], k_per,
                               use_permutation=use_permutation,
                               lane_align=lane_align, tp_shards=1)
            perms.append(sub.permutation + s * per)
            gidx.append(sub.group_index + s * k_per)
            sizes.append(sub.group_sizes)
        perm = np.concatenate(perms)
        inv = np.empty_like(perm)
        inv[perm] = np.arange(d)
        return PEGSpec(permutation=perm, inverse_permutation=inv,
                       group_index=np.concatenate(gidx),
                       num_groups=num_groups,
                       group_sizes=np.concatenate(sizes))

    if use_permutation:
        # Deterministic range-based permutation (paper §4): ascending range,
        # stable, so the largest-range (outlier) dims share the last group.
        perm = np.argsort(ranges, kind="stable")
    else:
        perm = np.arange(d)
    sizes = _even_group_sizes(d, num_groups, lane_align)
    group_index = np.repeat(np.arange(num_groups), sizes)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(d)
    return PEGSpec(permutation=perm.astype(np.int64),
                   inverse_permutation=inv.astype(np.int64),
                   group_index=group_index.astype(np.int64),
                   num_groups=num_groups,
                   group_sizes=sizes)


def group_index_natural_layout(spec: PEGSpec) -> np.ndarray:
    """Group id per *original* (un-permuted) dim — for runtime fake-quant when
    the permutation is NOT folded into the weights."""
    return spec.group_index[spec.inverse_permutation]


def overhead_params(d: int, num_groups: int) -> int:
    """Extra parameters per attention layer (paper §4): permutation indices +
    (scale, zero-point) per group for FFN input, output and sum."""
    return d + 2 * 3 * num_groups


# ---------------------------------------------------------------------------
# Folding the permutation into weights (TPU adaptation; paper Fig. 4).
# ---------------------------------------------------------------------------

def fold_permutation_into_ffn(perm: np.ndarray, ln_gamma, ln_beta,
                              w_in, b_in, w_out, b_out):
    """Rewrite (LN -> W_in -> act -> W_out -> +residual) so activations flow in
    permuted (group-sorted) layout with zero runtime gathers.

    Uses permutation-equivariance of LayerNorm and linears:
      LN params are permuted; W_in rows (input dim) are permuted; W_out
      columns (output dim) are permuted so the FFN *output* is produced
      directly in permuted layout, matching the permuted residual stream.
    The caller must also permute the upstream residual producer and the
    downstream consumer (next LN), i.e. apply this layer-wide.
    """
    p = np.asarray(perm)
    return (ln_gamma[..., p], ln_beta[..., p],
            w_in[p, :], b_in,
            w_out[:, p], None if b_out is None else b_out[..., p])


def split_linear_for_per_tensor_hw(spec: PEGSpec, w_in, w_out):
    """Paper Fig. 4: decompose W_in / W_out into K slices along the grouped
    embedding axis so PEG can be simulated with per-tensor quantized matmuls:
      y = sum_k  W_in[g_k, :]^T x[g_k]         (elementwise-summed partials)
      out[g_k] = (x W_out)[:, g_k]             (concatenated partials)
    Returns ([W_in_k], [W_out_k]) lists in permuted layout.
    """
    p = spec.permutation
    w_in_p = w_in[p, :]
    w_out_p = w_out[:, p]
    bounds = np.concatenate([[0], np.cumsum(spec.group_sizes)])
    ins = [w_in_p[bounds[k]:bounds[k + 1], :] for k in range(spec.num_groups)]
    outs = [w_out_p[:, bounds[k]:bounds[k + 1]] for k in range(spec.num_groups)]
    return ins, outs


def apply_permutation(x: jnp.ndarray, perm: np.ndarray, axis: int = -1):
    """Runtime gather fallback (used only in tests / non-folded mode)."""
    return jnp.take(x, jnp.asarray(perm), axis=axis)
