"""Deterministic synthetic data (no network access in this container).

Two generators:

  * ``SyntheticLM`` — next-token-predictable token streams for LM training
    (a planted k-gram Markov structure so the loss has signal).
  * ``SyntheticGLUE`` — classification/regression sentence-pair tasks with
    the shape of GLUE: each task hides a token-level rule (separator-token
    sensitive, mirroring the paper's [SEP]-attention analysis) that a small
    BERT can learn to >90% accuracy in a few hundred steps.

All sampling is derived from a seed + element index, so an iterator can be
checkpointed as (seed, position) and resumed exactly (fault tolerance).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import zlib

import numpy as np

CLS, SEP, PAD = 1, 2, 0      # special token ids (vocab reserves 0-9)


@dataclasses.dataclass(frozen=True)
class LMTaskConfig:
    vocab_size: int
    seq_len: int
    order: int = 2            # markov order of the planted structure
    temperature: float = 1.0


def _markov_table(vocab: int, order: int, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    # sparse-ish preferred-successor table: each context strongly prefers
    # a handful of tokens -> learnable signal
    ctx = 4096
    table = rng.dirichlet(np.full(vocab, 0.05), size=ctx)
    return table.astype(np.float32)


class SyntheticLM:
    def __init__(self, cfg: LMTaskConfig, seed: int = 0):
        self.cfg = cfg
        self.seed = seed
        self.table = _markov_table(cfg.vocab_size, cfg.order, seed)

    def batch(self, batch_size: int, index: int) -> Dict[str, np.ndarray]:
        """Deterministic: (seed, index) -> batch."""
        rng = np.random.RandomState((self.seed * 1_000_003 + index) % 2**31)
        v, t = self.cfg.vocab_size, self.cfg.seq_len
        toks = np.zeros((batch_size, t), np.int32)
        toks[:, 0] = rng.randint(10, v, size=batch_size)
        state = toks[:, 0].astype(np.int64)
        for i in range(1, t):
            ctx = (state * 2654435761 % self.table.shape[0])
            probs = self.table[ctx]
            cum = probs.cumsum(axis=1)
            u = rng.rand(batch_size, 1)
            nxt = (u < cum).argmax(axis=1)
            toks[:, i] = np.maximum(nxt, 10)
            state = (state * 31 + toks[:, i]) % (2**31 - 1)
        return {"tokens": toks, "labels": toks.copy()}


@dataclasses.dataclass(frozen=True)
class GLUETaskConfig:
    """A synthetic task shaped like one GLUE entry.

    content_vocab bounds the distinct content tokens (drawn from
    [10, 10+content_vocab)): small content vocabularies make the hidden
    rules learnable by a reduced BERT within a CPU training budget while the
    embedding table stays full-sized."""
    name: str
    vocab_size: int = 1024
    seq_len: int = 64
    num_labels: int = 2
    regression: bool = False
    rule: str = "match"        # match | parity | overlap | order | lookup
    content_vocab: int = 32


GLUE_SUITE = [
    GLUETaskConfig("syn-cola", rule="parity", content_vocab=8),
    GLUETaskConfig("syn-sst2", rule="lookup", content_vocab=32),
    GLUETaskConfig("syn-mrpc", rule="lookup", content_vocab=16),
    GLUETaskConfig("syn-stsb", rule="overlap", regression=True, num_labels=1,
                   content_vocab=32),
    GLUETaskConfig("syn-qqp", rule="overlap", content_vocab=32),
    GLUETaskConfig("syn-mnli", rule="order", num_labels=3, content_vocab=16),
    GLUETaskConfig("syn-qnli", rule="order", content_vocab=16),
    GLUETaskConfig("syn-rte", rule="order", content_vocab=8),
]


class SyntheticGLUE:
    """Sentence-pair tasks: [CLS] a... [SEP] b... [SEP] [PAD]...

    Rules (label depends on the pair, computable by an encoder):
      match:   label = 1 if multiset of b's first 3 content tokens ⊆ a
      parity:  label = parity of count of tokens < vocab/2 in a
      overlap: label/score = |a ∩ b| bucketed (regression: fraction)
      order:   label = 1 if first content token of a < first of b
    """

    def __init__(self, cfg: GLUETaskConfig, seed: int = 0):
        self.cfg = cfg
        self.seed = seed

    def batch(self, batch_size: int, index: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.RandomState(
            (zlib.crc32(c.name.encode()) % 65536 * 7 +
             self.seed * 1_000_003 + index) % 2**31)
        half = (c.seq_len - 3) // 2
        hi = min(10 + c.content_vocab, c.vocab_size)
        a = rng.randint(10, hi, size=(batch_size, half))
        b = rng.randint(10, hi, size=(batch_size, half))

        if c.rule == "lookup":
            # label = fixed random class of a's first content token — a pure
            # embedding-lookup task (the easiest probe of the pipeline)
            table = np.random.RandomState(
                zlib.crc32(c.name.encode()) % 65536) \
                .randint(0, c.num_labels, size=c.vocab_size)
            labels = table[a[:, 0]].astype(np.int32)
        elif c.rule == "match":
            # clean paired equality: half the batch copies b[0] <- a[0]
            # (label 1); the other half explicitly resamples b[0] != a[0]
            # so labels are noise-free
            m = batch_size // 2
            b[:m, 0] = a[:m, 0]
            neq = b[m:, 0] == a[m:, 0]
            while np.any(neq):
                b[m:, 0] = np.where(neq, rng.randint(10, hi, size=b[m:, 0].shape),
                                    b[m:, 0])
                neq = b[m:, 0] == a[m:, 0]
            labels = np.zeros(batch_size, np.int32)
            labels[:m] = c.num_labels - 1
        elif c.rule == "parity":
            # parity of {a[0] < mid} XOR {b[0] < mid}: a 2-feature parity —
            # genuinely harder than lookup/order (our CoLA analogue) but
            # within reach of a small encoder
            mid = 10 + c.content_vocab // 2
            labels = (((a[:, 0] < mid).astype(np.int32) +
                       (b[:, 0] < mid).astype(np.int32)) % 2).astype(np.int32)
        elif c.rule == "overlap":
            # per-position equality on the first 4 positions, constructed:
            # k ~ U{0..4} positions are copied, the rest explicitly differ;
            # regression score = k/4, classification label = k >= 2
            k = rng.randint(0, 5, size=batch_size)
            for i in range(batch_size):
                b[i, :k[i]] = a[i, :k[i]]
                for j in range(k[i], 4):
                    while b[i, j] == a[i, j]:
                        b[i, j] = rng.randint(10, hi)
            frac = k / 4.0
            if c.regression:
                labels = frac.astype(np.float32)
            else:
                labels = (k >= 2).astype(np.int32)
        elif c.rule == "order":
            if c.num_labels == 3:       # mnli-style: less / equal / greater
                labels = (np.sign(a[:, 0].astype(np.int64) -
                                  b[:, 0].astype(np.int64)) + 1).astype(np.int32)
            else:
                labels = (a[:, 0] < b[:, 0]).astype(np.int32)
        else:
            raise ValueError(c.rule)

        toks = np.full((batch_size, c.seq_len), PAD, np.int32)
        toks[:, 0] = CLS
        toks[:, 1:1 + half] = a
        toks[:, 1 + half] = SEP
        toks[:, 2 + half:2 + 2 * half] = b
        toks[:, 2 + 2 * half] = SEP
        type_ids = np.zeros((batch_size, c.seq_len), np.int32)
        type_ids[:, 2 + half:] = 1
        pad_mask = toks != PAD
        return {"tokens": toks, "type_ids": type_ids, "pad_mask": pad_mask,
                "labels": labels}

    def metric(self, preds: np.ndarray, labels: np.ndarray) -> float:
        """Accuracy (or pearson-like correlation for regression),
        in [0, 100] like GLUE scores."""
        if self.cfg.regression:
            p = preds - preds.mean()
            l = labels - labels.mean()
            denom = np.sqrt((p * p).sum() * (l * l).sum()) + 1e-9
            return float(100.0 * (p * l).sum() / denom)
        return float(100.0 * (preds == labels).mean())
