"""Production mesh construction.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — required for the smoke tests, which must see
one CPU device, while the dry-run sets xla_force_host_platform_device_count
before first jax init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_from_spec(spec: str):
    """e.g. "8x16" -> (data=8, model=16); "2x8x16" -> (pod, data, model).
    Used by elastic-resume (--mesh) in the launchers."""
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 2:
        axes = ("data", "model")
    elif len(dims) == 3:
        axes = ("pod", "data", "model")
    else:
        raise ValueError(f"bad mesh spec {spec!r}")
    return jax.make_mesh(dims, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(dims))
