"""Radix (prefix) cache over prompt token-ids at KV-block granularity.

Sits next to :class:`~repro.runtime.block_pool.BlockPool` and gives the
continuous scheduler O(suffix) admission for requests that share a prompt
prefix (system prompts, few-shot templates):

* **Match** (admission): walk the tree in ``block_size``-token steps and
  return the physical blocks backing the longest block-aligned cached
  prefix of the prompt. The scheduler maps them read-only into the lane's
  block table (``BlockPool.map_shared``) and prefills only the novel
  suffix through the append-mode chunk path.
* **Insert** (retirement): a retiring lane donates its FULL prompt blocks
  — each becomes (or joins) a tree node keyed by its ``block_size`` token
  ids. Blocks whose path already exists are NOT adopted (the donor's
  duplicates are freed normally); only newly adopted blocks are marked
  ``cached`` in the pool.
* **Evict** (pool pressure): when the free list runs dry the pool calls
  ``evict_lru`` — the least-recently-used subtree whose root block has
  refcount 0 is detached. Detached blocks with live refs merely lose
  matchability (their mappers are unaffected and the blocks free when
  the last ref drops); refcount-0 blocks return to the free list. Because
  lanes always map root-paths, a refcount-0 node can never shadow a
  referenced ancestor, so steady-state behavior degrades gracefully to
  the uncached pool.

The tree stores token ids as plain python tuples (one dict-keyed child
per block) — everything here is host-side bookkeeping between jitted
steps; physical block *contents* never move (except through the
scheduler's copy-on-write, which is outside the tree).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


class RadixNode:
    """One cached KV block: ``key`` is its block_size-token id tuple,
    ``block`` the physical block id backing it."""
    __slots__ = ("key", "block", "children", "parent", "last_used")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["RadixNode"]):
        self.key = key
        self.block = block
        self.children: Dict[Tuple[int, ...], "RadixNode"] = {}
        self.parent = parent
        self.last_used = 0


class RadixCache:
    """Block-granular prefix tree. All methods are O(prompt / block_size)
    dict walks; ``evict_lru`` is O(nodes) (the tree is small — one node
    per cached block)."""

    def __init__(self, block_size: int):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.block_size = block_size
        self.reset()

    def reset(self) -> None:
        self._root: Dict[Tuple[int, ...], RadixNode] = {}
        self._clock = 0
        self.n_nodes = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _chunks(self, tokens) -> List[Tuple[int, ...]]:
        toks = np.asarray(tokens).reshape(-1)
        bs = self.block_size
        return [tuple(int(t) for t in toks[i:i + bs])
                for i in range(0, (len(toks) // bs) * bs, bs)]

    # -- match --------------------------------------------------------------

    def match(self, tokens, max_blocks: Optional[int] = None
              ) -> Tuple[List[int], int]:
        """Longest block-aligned cached prefix of ``tokens``. Returns the
        physical blocks along the matched path (root first) and the number
        of matched tokens; bumps the LRU clock on every node of the path.
        ``max_blocks`` caps the match depth (the scheduler caps at
        ``(prompt_len - 1) // block_size`` so the novel suffix always
        keeps at least one token — the logits contract)."""
        blocks: List[int] = []
        level = self._root
        now = self._tick()
        for chunk in self._chunks(tokens):
            if max_blocks is not None and len(blocks) >= max_blocks:
                break
            node = level.get(chunk)
            if node is None:
                break
            node.last_used = now
            blocks.append(node.block)
            level = node.children
        return blocks, len(blocks) * self.block_size

    # -- insert -------------------------------------------------------------

    def insert(self, tokens, blocks: Sequence[int]) -> List[int]:
        """Donate the FULL prompt blocks of a retiring lane: ``blocks[i]``
        backs tokens ``[i*bs, (i+1)*bs)``. Existing path nodes keep their
        original physical block (the donor's duplicate is NOT adopted);
        new nodes adopt the donor's block. Returns the list of newly
        adopted blocks (the caller marks exactly those ``cached`` in the
        pool)."""
        chunks = self._chunks(tokens)
        if len(blocks) > len(chunks):
            raise ValueError(
                f"insert: {len(blocks)} blocks but only {len(chunks)} full "
                f"token chunks (donate full prompt blocks only)")
        adopted: List[int] = []
        level = self._root
        parent: Optional[RadixNode] = None
        now = self._tick()
        for chunk, block in zip(chunks, blocks):
            node = level.get(chunk)
            if node is None:
                node = RadixNode(chunk, int(block), parent)
                level[chunk] = node
                self.n_nodes += 1
                adopted.append(int(block))
            node.last_used = now
            parent = node
            level = node.children
        return adopted

    # -- evict --------------------------------------------------------------

    def _nodes(self):
        stack = list(self._root.values())
        while stack:
            nd = stack.pop()
            yield nd
            stack.extend(nd.children.values())

    def evict_lru(self, ref: Callable[[int], int]) -> List[int]:
        """Detach the least-recently-used subtree whose ROOT block has
        refcount 0 (per ``ref``) and return every block of that subtree
        (the pool un-caches them all and frees the refcount-0 ones).
        Returns [] when nothing is evictable."""
        victim = None
        for nd in self._nodes():
            if ref(nd.block) == 0 and (victim is None
                                       or nd.last_used < victim.last_used):
                victim = nd
        if victim is None:
            return []
        level = (self._root if victim.parent is None
                 else victim.parent.children)
        del level[victim.key]
        out: List[int] = []
        stack = [victim]
        while stack:
            nd = stack.pop()
            out.append(nd.block)
            self.n_nodes -= 1
            stack.extend(nd.children.values())
        return out
