"""Shared stub-model helpers for the serving-loop test files
(test_serve_loop.py: static scheduler; test_scheduler.py: continuous).

The stub LM is deterministic — next_token = (2 * tok + 1) % VOCAB — so both
schedulers can be checked token-for-token against ``golden`` without a real
model.
"""
import jax.numpy as jnp
import numpy as np

VOCAB = 32


def next_tok(tok: int) -> int:
    return (2 * tok + 1) % VOCAB


def next_arr(toks):
    return (2 * np.asarray(toks) + 1) % VOCAB


def onehot(tokens):
    return jnp.eye(VOCAB, dtype=jnp.float32)[jnp.asarray(tokens) % VOCAB]


def golden(prompt, n):
    """Expected greedy continuation of length n."""
    out, tok = [], int(prompt[-1])
    for _ in range(n):
        tok = next_tok(tok)
        out.append(tok)
    return out
