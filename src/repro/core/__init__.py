"""The paper's contribution: transformer quantization as a composable library.

Public API:
  quant_config  — QuantizerConfig / QuantizationPolicy + the paper's recipes
  quantizer     — uniform affine fake-quant with STE/LSQ gradients
  range_estimation — current/running min-max, MSE estimators
  peg           — per-embedding-group scheme + range-based permutation
  calibration   — QuantCtx threading + static range calibration
  qat           — learnable-range quantization-aware training
  adaround      — adaptive rounding PTQ refinement
  mixed_precision — Table-2/4 sensitivity + census helpers
  pipeline      — end-to-end PTQ driver
  deploy        — Mode.DEPLOY integer execution (packed int8 weights +
                  QTensor activations through the Pallas kernels)
  grad_compression — PEG-int8 cross-pod gradient all-reduce
"""
from repro.core.quant_config import (A8_DEFAULT, A16_DEFAULT, FP32, W8_DEFAULT,
                                     Granularity, QuantizationPolicy,
                                     QuantizerConfig, RangeEstimator,
                                     fp32_policy, low_bit_weight_policy,
                                     mixed_precision_policy, peg_config,
                                     peg_policy, w8a8_policy)
from repro.core.quantizer import (QuantParams, dequantize, fake_quant,
                                  params_from_range, quant_error, quantize,
                                  reduce_range)
from repro.core.range_estimation import (RangeState, estimate_weight_params,
                                         finalize, init_range_state,
                                         mse_search, observe)
from repro.core.peg import (PEGSpec, build_groups, fold_permutation_into_ffn,
                            group_index_natural_layout, overhead_params,
                            split_linear_for_per_tensor_hw)
from repro.core.calibration import (Mode, QuantCtx, QuantState,
                                    build_act_state, build_weight_state,
                                    collect_ranges, fp32_ctx)
from repro.core.pipeline import QuantizedModel, ptq
from repro.core.deploy import (ActQuant, KVQuant, QTensor, act_quant_for,
                               build_deploy, is_packed, kv_quant_for,
                               pack_linear)
