"""Kernel micro-benchmarks.

On this CPU container the Pallas kernels execute in interpret mode, so wall
times are NOT TPU-representative; we therefore report (a) interpret-mode
correctness timings for regression tracking and (b) the analytically derived
TPU-roofline time per call (bytes / HBM bw for the memory-bound quant
kernels; max(flops/peak, bytes/bw) for the matmuls) — the number a v5e
deployment would be judged against.

The FFN-chain section compares the *unfused* integer sequence
(LN+quant, PEG matmul to f32, gelu, re-quant, matmul) against the *fused*
deployment chain (``ln_quantize -> int8_matmul_peg`` with the
bias+gelu+requant epilogue ``-> int8_matmul``) — same math, strictly fewer
HBM bytes because the f32 hidden tensor never leaves VMEM.

The attention-decode section compares one serving decode step over an int8
KV cache (``int8_attend_decode``) against a bf16 cache with f32
dequant-attend — the decode step re-reads the whole cache per token, so
cache bytes/step is the roofline; int8 (+ per-slot f32 scales) roughly
halves it.

``python -m benchmarks.kernel_bench`` (or benchmarks/run.py --sections
kernels) also writes machine-readable ``BENCH_kernels.json`` so the perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import nibble, ops

PEAK_FLOPS = 197e12
HBM_BW = 819e9
JSON_PATH = "BENCH_kernels.json"


def _time(fn, *args, iters=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6     # us


def _row(name, us, roofline_us, hbm_bytes, variant="kernel"):
    return {"name": name, "interpret_us": round(us, 1),
            "tpu_roofline_us": round(roofline_us, 2),
            "hbm_bytes": int(hbm_bytes), "variant": variant}


def _matmul_roofline_us(m, k, n, *, a_bytes=1, o_bytes=4):
    flops = 2 * m * k * n
    bytes_moved = m * k * a_bytes + k * n + m * n * o_bytes
    return max(flops / (2 * PEAK_FLOPS),        # int8 ~2x bf16 MXU rate
               bytes_moved / HBM_BW) * 1e6, bytes_moved


def ffn_chain_bytes(t, d, f, *, fused: bool) -> int:
    """HBM traffic of the integer FFN chain (weights int8 either way).

    Both variants start from the fused LN+quantize kernel (seed-era fusion);
    what "unfused" lacks is the matmul EPILOGUE — its hidden activation
    round-trips HBM in f32 (matmul out, bias+gelu pass, re-quant pass)."""
    w_bytes = d * f + f * d
    if fused:
        # ln_quantize: f32 in, int8 out; both matmul intermediates int8.
        return (t * d * 4 + t * d) + (t * d + t * f) + (t * f + t * d * 4) \
            + w_bytes
    # ln_quantize, matmul1 -> f32, bias+gelu f32->f32, re-quant f32->int8,
    # matmul2 -> f32.
    return (t * d * 4 + t * d) + (t * d + t * f * 4) \
        + (t * f * 4 + t * f * 4) + (t * f * 4 + t * f) \
        + (t * f + t * d * 4) + w_bytes


def bench():
    rows = []
    key = jax.random.PRNGKey(0)

    # PEG fake-quant: (4096 tokens, 4096 dims, K=8)
    t, d, k = 4096, 4096, 8
    x = jax.random.normal(key, (t, d), jnp.float32)
    s = jnp.full((k,), 0.05)
    z = jnp.full((k,), 128.0)
    us = _time(lambda a: ops.peg_fake_quant(a, s, z), x)
    bytes_moved = t * d * 4 * 2
    rows.append(_row("peg_fake_quant_4kx4k", us,
                     bytes_moved / HBM_BW * 1e6, bytes_moved))

    # int8 matmul per-tensor: 1024x4096x4096
    m, kk, n = 1024, 4096, 4096
    a = jax.random.randint(key, (m, kk), -127, 128, jnp.int8)
    w = jax.random.randint(key, (kk, n), -127, 128, jnp.int8)
    us = _time(lambda a_: ops.int8_matmul(a_, w, s_a=0.02, s_w=0.01,
                                          block_m=256, block_n=256,
                                          block_k=512), a)
    tpu_us, bytes_moved = _matmul_roofline_us(m, kk, n)
    rows.append(_row("int8_matmul_1kx4kx4k", us, tpu_us, bytes_moved))

    # 4-bit weight payload: two int4 rows per byte, unpacked to int8 in
    # VMEM — same MXU work, half the HBM weight read
    w4 = jax.random.randint(key, (kk, n), -7, 8, jnp.int8)
    w4_pk = nibble.pack_rows(w4)
    us = _time(lambda a_: ops.int8_matmul(a_, w4_pk, s_a=0.02, s_w=0.01,
                                          w_bits=4, block_m=256,
                                          block_n=256, block_k=512), a)
    bytes4 = m * kk + kk * n // 2 + m * n * 4    # packed weight: K/2 x N
    roof4 = max(2 * m * kk * n / (2 * PEAK_FLOPS), bytes4 / HBM_BW) * 1e6
    rows.append(_row("int8_matmul_w4_1kx4kx4k", us, roof4, bytes4,
                     "w-int4"))

    # PEG int8 matmul (K=8 groups fused rescale)
    g = 8
    sg = jax.random.uniform(key, (g,), minval=0.01, maxval=0.05)
    zg = jnp.zeros((g,))
    us = _time(lambda a_: ops.int8_matmul_peg(a_, w, sg, zg, w_scale=0.01,
                                              block_m=256, block_n=256), a)
    rows.append(_row("int8_matmul_peg_k8", us, tpu_us, bytes_moved))

    # fused LN+quant: 4096 x 4096
    gma = jnp.ones((d,))
    beta = jnp.zeros((d,))
    us = _time(lambda a_: ops.ln_fake_quant(a_, gma, beta, 0.05, 128.0), x)
    bytes_moved = t * d * 4 * 2
    rows.append(_row("fused_ln_quant_4kx4k", us,
                     bytes_moved / HBM_BW * 1e6, bytes_moved))

    rows += bench_ffn_chain()
    rows += bench_attention_decode()
    return rows


def bench_attention_decode(b=4, s=2048, kv=8, g=2, hd=128):
    """Serving decode step: int8 KV cache (fused ``int8_attend_decode``)
    vs a bf16 cache with f32 dequant-attend. The decode step re-reads the
    whole cache every token, so cache bytes/step IS the roofline."""
    keys = jax.random.split(jax.random.PRNGKey(2), 7)
    q_q = jax.random.randint(keys[0], (b, kv, g, hd), -127, 128, jnp.int8)
    qs = jax.random.uniform(keys[1], (b, kv, g), minval=0.01, maxval=0.05)
    k_q = jax.random.randint(keys[2], (b, s, kv, hd), -127, 128, jnp.int8)
    ks_ = jax.random.uniform(keys[3], (b, s, kv), minval=0.01, maxval=0.05)
    v_q = jax.random.randint(keys[4], (b, s, kv, hd), -127, 128, jnp.int8)
    vs_ = jax.random.uniform(keys[5], (b, s, kv), minval=0.01, maxval=0.05)
    k_pos = jnp.broadcast_to(jnp.arange(s), (b, s)).astype(jnp.int32)
    q_pos = jnp.full((b,), s - 1, jnp.int32)

    def int8_path(qq):
        return ops.int8_attend_decode(qq, qs, k_q, ks_, v_q, vs_, k_pos,
                                      q_pos, chunk=512)

    # int4 cache: two cells per byte, unpacked in VMEM before the MXU q.k
    k4_pk = nibble.pack_nibbles(jnp.clip(k_q, -8, 7))
    v4_pk = nibble.pack_nibbles(jnp.clip(v_q, -8, 7))

    def int4_path(qq):
        return ops.int8_attend_decode(qq, qs, k4_pk, ks_, v4_pk, vs_,
                                      k_pos, q_pos, kv_bits=4, chunk=512)

    k16 = (k_q.astype(jnp.float32) * ks_[..., None]).astype(jnp.bfloat16)
    v16 = (v_q.astype(jnp.float32) * vs_[..., None]).astype(jnp.bfloat16)
    qf = (q_q.astype(jnp.float32) * qs[..., None])

    @jax.jit
    def bf16_path(qh):
        sc = jnp.einsum("bkgd,bskd->bkgs", qh,
                        k16.astype(jnp.float32))
        valid = (k_pos >= 0) & (k_pos <= q_pos[:, None])
        sc = jnp.where(valid[:, None, None, :], sc, -1e30)
        p = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bkgs,bskd->bkgd", p, v16.astype(jnp.float32))

    # cache bytes/step: packed/int8 payloads + f32 per-slot scales vs
    # bf16 k/v (int4 packs two cells per byte: hd/2 payload bytes)
    int4_cache = b * s * kv * (hd // 2 + 4) * 2
    int8_cache = b * s * kv * (hd * 1 + 4) * 2
    bf16_cache = b * s * kv * hd * 2 * 2
    q_out = b * kv * g * hd * (1 + 4)            # q int8 + f32 out (both tiny)
    rows = []
    for name, fn, arg, cache_bytes, variant in [
            ("attn_decode_int4kv", int4_path, q_q, int4_cache, "kv-int4"),
            ("attn_decode_int8kv", int8_path, q_q, int8_cache, "kv-int8"),
            ("attn_decode_bf16kv", bf16_path, qf, bf16_cache, "kv-bf16")]:
        us = _time(fn, arg)
        nbytes = cache_bytes + q_out
        flops = 2 * b * kv * g * hd * s * 2      # q.k + p.v
        roof = max(flops / (2 * PEAK_FLOPS), nbytes / HBM_BW) * 1e6
        row = _row(f"{name}_b{b}_s{s}_h{kv * g}x{hd}", us, roof, nbytes,
                   variant)
        row["cache_bytes_step"] = int(cache_bytes)
        rows.append(row)
    return rows


def bench_ffn_chain(t=512, d=512, f=2048, groups=4):
    """Unfused vs fused integer FFN chain (deployment hot path)."""
    keys = jax.random.split(jax.random.PRNGKey(1), 6)
    x = jax.random.normal(keys[0], (t, d), jnp.float32)
    gamma = jnp.ones((d,))
    beta = jnp.zeros((d,))
    w1 = jax.random.randint(keys[1], (d, f), -127, 128, jnp.int8)
    w2 = jax.random.randint(keys[2], (f, d), -127, 128, jnp.int8)
    bias = jax.random.normal(keys[3], (f,)) * 0.1
    sg = jax.random.uniform(keys[4], (groups,), minval=0.01, maxval=0.05)
    zg = jnp.round(jax.random.uniform(keys[5], (groups,), minval=-1.0,
                                      maxval=1.0) * 10)
    s_h, z_h = jnp.asarray(0.03), jnp.asarray(-5.0)
    s_w1 = s_w2 = jnp.asarray(0.01)

    def unfused(xx):
        a_q = ops.ln_quantize(xx, gamma, beta, sg, zg, qmin=-128, qmax=127)
        h = ops.int8_matmul_peg(a_q, w1, sg, zg, w_scale=s_w1)
        h = jax.nn.gelu(h + bias, approximate=True)
        h_q = ops.peg_quantize(h, s_h[None], z_h[None], qmin=-128, qmax=127)
        return ops.int8_matmul(h_q, w2, s_a=s_h, s_w=s_w2, z_a=z_h)

    def fused(xx):
        a_q = ops.ln_quantize(xx, gamma, beta, sg, zg, qmin=-128, qmax=127)
        h_q = ops.int8_matmul_peg(a_q, w1, sg, zg, w_scale=s_w1, bias=bias,
                                  activation="gelu", out_scale=s_h,
                                  out_zp=z_h)
        return ops.int8_matmul(h_q, w2, s_a=s_h, s_w=s_w2, z_a=z_h)

    # same math: assert parity before timing
    np.testing.assert_allclose(np.asarray(unfused(x)), np.asarray(fused(x)),
                               rtol=1e-3, atol=1e-2)

    rows = []
    for name, fn, is_fused in [("ffn_chain_unfused", unfused, False),
                               ("ffn_chain_fused", fused, True)]:
        us = _time(fn, x)
        nbytes = ffn_chain_bytes(t, d, f, fused=is_fused)
        flops = 2 * t * d * f * 2
        roof = max(flops / (2 * PEAK_FLOPS), nbytes / HBM_BW) * 1e6
        rows.append(_row(f"{name}_{t}x{d}x{f}", us, roof, nbytes,
                         "fused" if is_fused else "unfused"))
    return rows


def report(rows):
    lines = [f"{r['name']},{r['interpret_us']:.1f},"
             f"tpu_roofline_us={r['tpu_roofline_us']:.2f},"
             f"hbm_bytes={r['hbm_bytes']}" for r in rows]
    fused = {r["variant"]: r for r in rows if r["variant"] in
             ("fused", "unfused")}
    if len(fused) == 2:
        ratio = fused["unfused"]["hbm_bytes"] / fused["fused"]["hbm_bytes"]
        lines.append(f"# fused FFN chain moves {ratio:.2f}x fewer HBM bytes "
                     "than the unfused sequence")
    kvs = {r["variant"]: r for r in rows if r["variant"] in
           ("kv-int4", "kv-int8", "kv-bf16")}
    if len(kvs) >= 2:
        ratio = kvs["kv-bf16"]["cache_bytes_step"] / \
            kvs["kv-int8"]["cache_bytes_step"]
        lines.append(f"# int8 KV cache reads {ratio:.2f}x fewer cache bytes "
                     "per decode step than bf16")
    if "kv-int4" in kvs:
        ratio = kvs["kv-int4"]["cache_bytes_step"] / \
            kvs["kv-int8"]["cache_bytes_step"]
        lines.append(f"# int4 KV cache reads {ratio:.2f}x the int8 cache "
                     "bytes per decode step (target <= 0.55)")
    mm = {r["name"]: r for r in rows}
    if "int8_matmul_w4_1kx4kx4k" in mm and "int8_matmul_1kx4kx4k" in mm:
        ratio = mm["int8_matmul_w4_1kx4kx4k"]["hbm_bytes"] / \
            mm["int8_matmul_1kx4kx4k"]["hbm_bytes"]
        lines.append(f"# int4 weight payload moves {ratio:.2f}x the int8 "
                     "matmul HBM bytes (weight read halved)")
    return "\n".join(lines)


def write_json(rows, path=JSON_PATH):
    with open(path, "w") as fh:
        json.dump(rows, fh, indent=1)
    return path


if __name__ == "__main__":
    rows = bench()
    print(report(rows))
    print(f"# wrote {write_json(rows)}")
