from repro.runtime.async_serve import AsyncServer, TokenStream
from repro.runtime.block_pool import BlockPool, blocks_for_tokens
from repro.runtime.engine import (DecodeState, Engine, LanePayload,
                                  make_engine, serve_engine)
from repro.runtime.fault_tolerance import (PreemptionGuard, RestartPolicy,
                                           StragglerWatchdog)
from repro.runtime.radix_cache import RadixCache, RadixNode
from repro.runtime.serve_loop import (Request, RequestLatency, Scheduler,
                                      ServeStats, serve, serve_batch,
                                      serve_continuous)
from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                 make_decode_step, make_encoder_forward,
                                 make_prefill_step, make_train_step)
from repro.runtime.telemetry import (MetricsLogger, QuantHealth,
                                     ServeTelemetry, Tracer)
from repro.runtime.train_loop import TrainLoopConfig, run_train_loop
