from repro.data.pipeline import DataPipeline, IteratorState, shard_batch
from repro.data.synthetic import (GLUE_SUITE, GLUETaskConfig, LMTaskConfig,
                                  SyntheticGLUE, SyntheticLM)
