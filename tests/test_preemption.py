"""Over-commit admission, lane preemption, priority tiers, and the
queue-wait / latency accounting they rely on (runtime.serve_loop with
over_commit=True + runtime.block_pool.try_grow + runtime.steps.make_swap_steps).

Coverage layers, mirroring tests/test_chunked_prefill.py /
test_prefix_cache.py:

* Latency bookkeeping unit tests: queue_wait_steps under pool
  backpressure (per-request and aggregate), the _Book.track_pool
  first-peak fragmentation sample (strict >, a later equal-height peak
  cannot overwrite it), and zero-quota requests never growing a
  request_latency entry (their absence must not crash finalize's tier
  percentiles).
* Golden stub-model over-commit tests: a pool below the workload's
  worst-case demand still serves every request token-for-token
  (preemptions > 0) — drop mode recomputes (recomputed_tokens > 0),
  swap mode restores bit-state (swapped_blocks > 0, nothing recomputed);
  priority tiers reorder admission (high tier jumps the FIFO queue,
  low-tier lanes are the preemption victims); decode_ratio paces decode
  steps against chunk steps; the scheduler deadlock guard raises instead
  of spinning when a (broken) pool can never seat anything.
* Property sweeps (seeded + hypothesis when installed): radix-cache LRU
  eviction racing preemption — a freshly drawn block is never mapped,
  cached, or ref-held elsewhere (no resurrected freed blocks), refcounts
  drain to zero, free + cached partition the pool.
* Real-model invariants on gemma2-2b-reduced: a preempted over-commit
  run (pool below total worst-case demand) emits the same greedy tokens
  as an unconstrained reservation run — drop and swap modes, f32 KV and
  the calibrated deploy-int8 path for both kv-bit widths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.runtime import (BlockPool, RadixCache, Request, blocks_for_tokens,
                           serve, serve_continuous)
from repro.runtime.serve_loop import ServeStats, _Book
from repro.runtime.steps import (make_admit_step, make_chunk_prefill_step,
                                 make_decode_step, make_prefill_step,
                                 make_swap_steps)
from serve_testlib import golden as _golden
from serve_testlib import next_arr as _next_arr
from serve_testlib import onehot as _onehot

pytestmark = [pytest.mark.serve, pytest.mark.preempt]


class OCStub:
    """StubChunkModel twin for over-commit serving: deterministic
    next_token = (2 * tok + 1) % VOCAB, position-free, so drop-mode
    recompute and swap-mode restore must both reproduce the golden
    continuation exactly. Stub swap fns carry a dummy payload (the stub
    cache holds no per-block state)."""

    def __init__(self):
        self.calls = []

    def init_cache(self, batch):
        return {"kv": jnp.zeros((batch, 4), jnp.float32)}

    def admit(self, tokens, positions, admit_mask, cache):
        self.calls.append("admit")
        return _onehot(_next_arr(tokens)), cache

    def chunk(self, tokens, positions, reset_mask, cache):
        self.calls.append("chunk")
        return _onehot(_next_arr(tokens)), cache

    def decode(self, tokens, pos, cache):
        self.calls.append("decode")
        return _onehot(_next_arr(tokens)), cache

    def swap_out(self, cache, ids):
        self.calls.append("swap_out")
        return {"blocks": jnp.zeros((int(ids.shape[0]), 1), jnp.float32)}

    def swap_in(self, cache, ids, payload):
        self.calls.append("swap_in")
        return cache


def _serve_oc(reqs, *, slots=2, bs=4, width=8, num_blocks=8, swap=False,
              radix=False, prefill_chunk=4, decode_ratio=1,
              over_commit=True, pool_cls=BlockPool):
    m = OCStub()
    pool = pool_cls(num_blocks, bs, slots, width)
    rc = RadixCache(bs) if radix else None
    stats = serve_continuous(
        m.admit, m.decode, m.init_cache, reqs, batch_slots=slots,
        block_pool=pool, chunk_fn=m.chunk, prefill_chunk=prefill_chunk,
        radix_cache=rc, over_commit=over_commit,
        swap_out_fn=m.swap_out if swap else None,
        swap_in_fn=m.swap_in if swap else None,
        decode_ratio=decode_ratio)
    return m, stats, pool, rc


def _reqs(specs, priorities=None):
    """Distinct prompts (head token varies per rid) of (prompt_len, quota),
    with optional per-request priority tiers."""
    pri = priorities or [0] * len(specs)
    return [Request(rid=i, prompt=np.full(n, 3 + i, np.int32),
                    max_new_tokens=q, priority=p)
            for i, ((n, q), p) in enumerate(zip(specs, pri))]


def _drained(pool, rc=None):
    """Post-drain invariants (mirrors test_prefix_cache._check_drained):
    refcounts conserved, free + cached partition the pool."""
    assert pool.blocks_reserved == 0
    assert all(pool.block_ref(b) == 0 for b in range(pool.num_blocks))
    assert (pool.table == -1).all()
    free = list(pool._free)
    cached = [b for b in range(pool.num_blocks) if pool.is_cached(b)]
    assert len(free) == len(set(free))           # no double-free
    assert sorted(free + cached) == list(range(pool.num_blocks))
    assert pool.blocks_in_use == len(cached)
    if rc is not None:
        assert pool.blocks_cached == rc.n_nodes


# ---------------------------------------------------------------------------
# Queue-wait accounting (satellite: enqueue step + queue_wait_steps)
# ---------------------------------------------------------------------------


class TestQueueWait:
    def test_backpressure_accrues_queue_wait(self):
        """Legacy (worst-case reservation) paged serving: two lanes fill
        the pool, so the third request waits at the queue head until a
        lane retires — its wait is visible per-request and in aggregate."""
        reqs = _reqs([(4, 5)] * 3)
        m, stats, pool, _ = _serve_oc(reqs, slots=2, width=4, num_blocks=4,
                                      over_commit=False)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 5)
        lat = stats.request_latency
        assert lat[0].enqueue_step == 0 and lat[0].queue_wait_steps == 0
        assert lat[1].queue_wait_steps == 0
        assert lat[2].queue_wait_steps > 0
        assert lat[2].queue_wait_steps == (lat[2].admit_step
                                           - lat[2].enqueue_step)
        assert stats.queue_wait_steps == sum(
            l.queue_wait_steps for l in lat.values())

    def test_unpressured_requests_wait_zero(self):
        reqs = _reqs([(4, 2), (4, 2)])
        _, stats, _, _ = _serve_oc(reqs, slots=2, num_blocks=8,
                                   over_commit=False)
        assert stats.queue_wait_steps == 0
        for l in stats.request_latency.values():
            assert l.queue_wait_steps == 0
            assert l.admit_step == l.enqueue_step == 0

    def test_legacy_stats_stay_zero_without_over_commit(self):
        reqs = _reqs([(4, 3)] * 3)
        _, stats, _, _ = _serve_oc(reqs, slots=2, width=4, num_blocks=4,
                                   over_commit=False)
        assert stats.preemptions == 0
        assert stats.swapped_blocks == 0
        assert stats.recomputed_tokens == 0


# ---------------------------------------------------------------------------
# track_pool first-peak fragmentation sample (satellite bugfix)
# ---------------------------------------------------------------------------


class _FakePool:
    def __init__(self):
        self.blocks_in_use = 0
        self.shared_blocks = 0
        self.frag = 0.0

    def fragmentation(self, live_tokens):
        return self.frag


class TestTrackPoolFirstPeak:
    def test_equal_height_peak_keeps_first_sample(self):
        stats = ServeStats()
        book = _Book(stats, 2)
        pool = _FakePool()
        pool.blocks_in_use, pool.frag = 4, 0.25
        book.track_pool(pool, 10, 1)
        assert stats.blocks_in_use == 4
        assert stats.block_fragmentation == 0.25
        # a LATER peak of the same height must not overwrite the sample
        pool.frag = 0.9
        book.track_pool(pool, 2, 1)
        assert stats.blocks_in_use == 4
        assert stats.block_fragmentation == 0.25
        # a strictly higher peak does resample
        pool.blocks_in_use, pool.frag = 6, 0.5
        book.track_pool(pool, 20, 1)
        assert stats.blocks_in_use == 6
        assert stats.block_fragmentation == 0.5


# ---------------------------------------------------------------------------
# Zero-quota requests (satellite: no latency entry, consumers guarded)
# ---------------------------------------------------------------------------


class TestZeroQuota:
    def test_zero_quota_mixed_into_over_commit_run(self):
        reqs = _reqs([(4, 3), (4, 0), (4, 2), (3, 0)])
        _, stats, pool, _ = _serve_oc(reqs, slots=2, num_blocks=6)
        assert reqs[1].done and reqs[1].tokens_out == []
        assert reqs[3].done and reqs[3].tokens_out == []
        assert reqs[0].tokens_out == _golden(reqs[0].prompt, 3)
        assert reqs[2].tokens_out == _golden(reqs[2].prompt, 2)
        # zero-quota requests never enqueue: no latency entry at all
        assert set(stats.request_latency) == {0, 2}
        # finalize's tier percentiles must survive the sparse entries
        assert stats.tier_latency[0].requests == 2
        _drained(pool)

    def test_all_zero_quota_finalizes_empty(self):
        reqs = _reqs([(4, 0), (2, 0)])
        _, stats, _, _ = _serve_oc(reqs, slots=1, num_blocks=4)
        assert stats.request_latency == {}
        assert stats.tier_latency == {}
        assert stats.tokens_generated == 0


# ---------------------------------------------------------------------------
# Golden over-commit serving: drop + swap preemption
# ---------------------------------------------------------------------------

# four requests, each worst case blocks_for_tokens(4+12-1, 4) = 4 blocks;
# the 6-block pool is below even two lanes' combined demand (8), so
# growth MUST preempt — and still serve every golden token
_OC_SPECS = [(4, 12)] * 4


class TestOverCommitGolden:
    def test_drop_mode_preempts_and_recomputes(self):
        reqs = _reqs(_OC_SPECS)
        m, stats, pool, _ = _serve_oc(reqs, slots=2, num_blocks=6)
        for r in reqs:
            assert r.done
            assert r.tokens_out == _golden(r.prompt, 12)
        assert stats.preemptions > 0
        assert stats.recomputed_tokens > 0       # drop mode re-prefills
        assert stats.swapped_blocks == 0
        assert stats.queue_wait_steps > 0        # requeued lanes waited
        _drained(pool)

    def test_swap_mode_preempts_without_recompute(self):
        reqs = _reqs(_OC_SPECS)
        m, stats, pool, _ = _serve_oc(reqs, slots=2, num_blocks=6,
                                      swap=True)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 12)
        assert stats.preemptions > 0
        assert stats.swapped_blocks > 0
        assert stats.recomputed_tokens == 0      # bit-exact resume
        assert "swap_out" in m.calls and "swap_in" in m.calls
        _drained(pool)

    def test_over_commit_admits_beyond_worst_case(self):
        """The whole point: summed worst-case reservations (2 + 3 blocks)
        exceed the 4-block pool, so legacy admission serializes — but the
        instantaneous demand peaks at 4 (the short request frees its
        blocks before the long one grows), so over-commit runs both lanes
        concurrently without a single preemption."""
        specs = [(4, 2), (4, 6)]
        reqs = _reqs(specs)
        m, stats, pool, _ = _serve_oc(reqs, slots=2, num_blocks=4)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        assert stats.preemptions == 0
        lat = stats.request_latency
        assert lat[0].admit_step == 0 and lat[1].admit_step == 0
        _drained(pool)
        # the worst-case-reservation baseline on the same pool serializes
        legacy = _reqs(specs)
        _, s_legacy, _, _ = _serve_oc(legacy, slots=2, num_blocks=4,
                                      over_commit=False)
        assert s_legacy.request_latency[1].admit_step > 0

    def test_preempted_equals_unpreempted(self):
        specs = [(5, 9), (4, 11), (6, 7), (3, 10), (4, 8)]
        for swap in (False, True):
            tight = _reqs(specs)
            _, s_tight, pool, _ = _serve_oc(tight, slots=2, num_blocks=5,
                                            swap=swap)
            roomy = _reqs(specs)
            _, s_roomy, _, _ = _serve_oc(roomy, slots=2, num_blocks=16)
            assert s_tight.preemptions > 0, swap
            assert s_roomy.preemptions == 0
            for t, r in zip(tight, roomy):
                assert t.tokens_out == r.tokens_out, (swap, t.rid)
            _drained(pool)


# ---------------------------------------------------------------------------
# Priority tiers
# ---------------------------------------------------------------------------


class TestPriorityTiers:
    def test_high_tier_jumps_fifo_queue(self):
        """One lane: the tier-1 arrival seated FIRST although it queued
        behind a tier-0 request."""
        reqs = _reqs([(4, 3), (4, 3)], priorities=[0, 1])
        _, stats, _, _ = _serve_oc(reqs, slots=1, num_blocks=8)
        lat = stats.request_latency
        assert lat[1].admit_step == 0 and lat[1].queue_wait_steps == 0
        assert lat[0].admit_step > 0 and lat[0].queue_wait_steps > 0
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 3)
        assert stats.tier_latency[1].first_token_p50 \
            < stats.tier_latency[0].first_token_p50

    def test_fifo_ignores_priority_without_over_commit(self):
        reqs = _reqs([(4, 3), (4, 3)], priorities=[0, 1])
        _, stats, _, _ = _serve_oc(reqs, slots=1, num_blocks=8,
                                   over_commit=False)
        lat = stats.request_latency
        assert lat[0].admit_step == 0            # arrival order held
        assert lat[1].admit_step > 0

    def test_growth_preempts_lowest_tier_first(self):
        """Pool pressure from a long high-tier decode evicts the tier-0
        lane, never the tier-1 demander: the high tier rides through with
        zero queue wait while tier 0 pays the preemption."""
        reqs = _reqs([(4, 16), (4, 8), (4, 8), (4, 8)],
                     priorities=[1, 0, 0, 0])
        _, stats, pool, _ = _serve_oc(reqs, slots=2, num_blocks=6)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        assert stats.preemptions > 0
        lat = stats.request_latency
        assert lat[0].queue_wait_steps == 0      # tier 1: never preempted
        assert any(lat[i].queue_wait_steps > 0 for i in (1, 2, 3))
        assert stats.tier_latency[1].requests == 1
        assert stats.tier_latency[0].requests == 3
        assert stats.tier_latency[1].first_token_p99 \
            <= stats.tier_latency[0].first_token_p99
        _drained(pool)

    def test_same_tier_victim_is_youngest(self):
        """All one tier: growth preemption picks the youngest lane, so
        the oldest admission always completes first (no livelock)."""
        reqs = _reqs(_OC_SPECS)
        _, stats, _, _ = _serve_oc(reqs, slots=2, num_blocks=6)
        lat = stats.request_latency
        assert stats.preemptions > 0
        assert lat[0].queue_wait_steps == 0      # oldest never evicted
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 12)


# ---------------------------------------------------------------------------
# decode:chunk pacing
# ---------------------------------------------------------------------------


class TestDecodeRatio:
    def test_ratio_two_interleaves_two_decodes_per_chunk(self):
        reqs = [Request(rid=0, prompt=np.asarray([3]), max_new_tokens=8),
                Request(rid=1, prompt=np.asarray([5] * 12),
                        max_new_tokens=2)]
        m = OCStub()
        serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                         batch_slots=2, chunk_fn=m.chunk, prefill_chunk=3,
                         decode_ratio=2)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
        assert m.calls[:9] == ["chunk", "decode", "decode",
                               "chunk", "decode", "decode",
                               "chunk", "decode", "decode"]

    def test_ratio_one_is_legacy_interleave(self):
        specs = [(1, 8), (12, 2)]
        a = [Request(rid=i, prompt=np.full(n, 4 + i, np.int32),
                     max_new_tokens=q) for i, (n, q) in enumerate(specs)]
        b = [Request(rid=i, prompt=np.full(n, 4 + i, np.int32),
                     max_new_tokens=q) for i, (n, q) in enumerate(specs)]
        ma = OCStub()
        serve_continuous(ma.admit, ma.decode, ma.init_cache, a,
                         batch_slots=2, chunk_fn=ma.chunk, prefill_chunk=3)
        mb = OCStub()
        serve_continuous(mb.admit, mb.decode, mb.init_cache, b,
                         batch_slots=2, chunk_fn=mb.chunk, prefill_chunk=3,
                         decode_ratio=1)
        assert ma.calls == mb.calls
        for x, y in zip(a, b):
            assert x.tokens_out == y.tokens_out

    def test_invalid_configs_raise(self):
        m = OCStub()
        reqs = _reqs([(4, 1)])
        with pytest.raises(ValueError, match="decode_ratio"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, chunk_fn=m.chunk,
                             decode_ratio=0)
        with pytest.raises(ValueError, match="chunk_fn"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, decode_ratio=2)
        with pytest.raises(ValueError, match="block_pool"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, chunk_fn=m.chunk,
                             over_commit=True)
        with pytest.raises(ValueError, match="chunk_fn"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, block_pool=BlockPool(8, 4, 1, 8),
                             over_commit=True)
        with pytest.raises(ValueError, match="pair"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, block_pool=BlockPool(8, 4, 1, 8),
                             chunk_fn=m.chunk, over_commit=True,
                             swap_out_fn=m.swap_out)
        with pytest.raises(ValueError, match="over_commit"):
            serve_continuous(m.admit, m.decode, m.init_cache, reqs,
                             batch_slots=1, block_pool=BlockPool(8, 4, 1, 8),
                             chunk_fn=m.chunk, swap_out_fn=m.swap_out,
                             swap_in_fn=m.swap_in)


# ---------------------------------------------------------------------------
# Deadlock guard (the formerly "unreachable" degradation path)
# ---------------------------------------------------------------------------


class _StingyPool(BlockPool):
    """A pool that passes the up-front capacity check but can never
    actually supply a block — the contract violation the deadlock guard
    exists to surface."""

    def available_blocks(self):
        return 0


class TestDeadlockGuard:
    def test_unseatable_queue_raises_instead_of_spinning(self):
        reqs = _reqs([(4, 2)])
        with pytest.raises(RuntimeError, match="deadlock"):
            _serve_oc(reqs, slots=1, num_blocks=8, pool_cls=_StingyPool)


# ---------------------------------------------------------------------------
# Radix eviction racing preemption (satellite: no resurrected blocks)
# ---------------------------------------------------------------------------


class _CheckedPool(BlockPool):
    """Asserts on every free-list draw that the block really is free:
    unmapped in every lane, not cached, refcount zero — a resurrected
    block (freed by preemption while the radix cache still pointed at it)
    trips this immediately instead of corrupting a later lane."""

    def _pop_free(self, n):
        blocks = super()._pop_free(n)
        mapped = {int(b) for b in self.table.ravel() if b >= 0}
        for b in blocks:
            assert b not in mapped, f"block {b} drawn while mapped"
            assert not self.is_cached(b), f"block {b} drawn while cached"
            assert self.block_ref(b) == 0, f"block {b} drawn with refs"
        return blocks


def _shared_reqs(specs, shared):
    out = []
    for i, (n, q) in enumerate(specs):
        tail = np.full(n - len(shared), 10 + i, np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([shared, tail]),
                           max_new_tokens=q))
    return out


def _run_preempt_radix(specs, slots, num_blocks, shared_len):
    pre = np.arange(1, shared_len + 1, dtype=np.int32)
    reqs = _shared_reqs([(shared_len + n, q) for n, q in specs], pre)
    m, stats, pool, rc = _serve_oc(reqs, slots=slots, bs=4, width=8,
                                   num_blocks=num_blocks, radix=True,
                                   pool_cls=_CheckedPool)
    for r in reqs:
        assert r.done
        assert r.tokens_out == _golden(r.prompt, r.max_new_tokens)
    _drained(pool, rc)
    return stats


class TestPreemptionRadixConservation:
    def test_seeded_sweep(self):
        """Seeded workloads on pools barely above the single-request
        worst case: preemption interleaves with LRU eviction and
        drop-mode donation, yet refcounts conserve and no freed block is
        ever resurrected."""
        rng = np.random.RandomState(7)
        preempted = 0
        for _ in range(12):
            shared_len = int(rng.choice([0, 4, 8]))
            n = rng.randint(2, 6)
            specs = [(rng.randint(1, 6), rng.randint(1, 10))
                     for _ in range(n)]
            worst = max(blocks_for_tokens(shared_len + p + q - 1, 4)
                        for p, q in specs)
            slots = rng.randint(1, 4)
            blocks = worst + rng.randint(0, 3)
            stats = _run_preempt_radix(specs, slots, blocks, shared_len)
            preempted += stats.preemptions
        assert preempted > 0                     # the sweep exercised it

    def test_preemption_with_prefix_hits_recomputes_suffix_only(self):
        """Drop-mode resume through a warm radix cache: the re-prefill
        recompute is bounded by the novel suffix, not the full prompt."""
        pre = np.arange(1, 9, dtype=np.int32)    # two cacheable blocks
        specs = [(12, 8)] * 3
        reqs = _shared_reqs(specs, pre)
        m, stats, pool, rc = _serve_oc(reqs, slots=2, bs=4, width=8,
                                       num_blocks=7, radix=True)
        for r in reqs:
            assert r.tokens_out == _golden(r.prompt, 8)
        assert stats.preemptions > 0
        assert stats.prefix_hit_tokens > 0
        _drained(pool, rc)


try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                # pragma: no cover - dev-only dependency
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    class TestPreemptionHypothesis:
        @settings(max_examples=25, deadline=None)
        @given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 9)),
                        min_size=2, max_size=6),
               st.integers(1, 3), st.integers(0, 2),
               st.sampled_from([0, 4, 8]))
        def test_refcounts_conserved_under_preemption(self, specs, slots,
                                                      extra, shared_len):
            worst = max(blocks_for_tokens(shared_len + p + q - 1, 4)
                        for p, q in specs)
            _run_preempt_radix(specs, slots, worst + extra, shared_len)
else:                              # keep the skip visible in test reports
    @pytest.mark.skip(reason="hypothesis not installed "
                             "(see requirements-dev.txt)")
    def test_refcounts_conserved_under_preemption():
        pass


# ---------------------------------------------------------------------------
# Real-model invariants (gemma2-2b-reduced)
# ---------------------------------------------------------------------------

MAX_LEN = 32
BS = 8


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("gemma2-2b").reduced()
    params = tfm.init_params(cfg, jax.random.PRNGKey(0), stacked=True,
                             dtype=jnp.float32)
    return cfg, params


_STEP_CACHE = {}


def _steps(cfg, ctx_factory=None):
    key = (cfg.name, ctx_factory)
    if key not in _STEP_CACHE:
        so, si = make_swap_steps()
        _STEP_CACHE[key] = (
            jax.jit(make_admit_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_chunk_prefill_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_decode_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(make_prefill_step(cfg, ctx_factory=ctx_factory)),
            jax.jit(so), jax.jit(si, donate_argnums=(0,)))
    return _STEP_CACHE[key]


def _serve_oc_real(cfg, params, reqs, *, kv_bits=16, slots=2,
                   num_blocks=None, swap=False, over_commit=True,
                   ctx_factory=None):
    admit, chunkstep, decode, prefill, so, si = _steps(cfg, ctx_factory)
    width = tfm.paged_lane_blocks(cfg, MAX_LEN, BS)
    num_blocks = num_blocks or slots * width
    pool = BlockPool(num_blocks, BS, slots, width)

    def init(b):
        return tfm.init_cache(cfg, b, MAX_LEN, dtype=jnp.float32,
                              kv_bits=kv_bits, paged=True, block_size=BS,
                              num_blocks=num_blocks, mapped=False)

    stats = serve(prefill, admit, decode, init, params, reqs,
                  scheduler="continuous", batch_slots=slots,
                  max_len=MAX_LEN, block_pool=pool, chunk_step=chunkstep,
                  prefill_chunk=BS, over_commit=over_commit,
                  swap_out_fn=so if swap else None,
                  swap_in_fn=si if swap else None,
                  write_caps=tfm.attn_write_caps(cfg, MAX_LEN, BS),
                  ring_tokens=tfm.paged_ring_tokens(cfg, MAX_LEN, BS))
    return stats, pool


def _mk_reqs(seed, cfg, specs, priorities=None):
    rng = np.random.RandomState(seed)
    pri = priorities or [0] * len(specs)
    return [Request(rid=i,
                    prompt=rng.randint(1, cfg.vocab_size,
                                       size=n).astype(np.int32),
                    max_new_tokens=q, priority=p)
            for i, ((n, q), p) in enumerate(zip(specs, pri))]


# 4 requests x up to 22 cache cells each: worst case 3 blocks per lane,
# so a 4-block pool is under two lanes' combined demand (6) and must
# preempt, while any single request still fits (capacity contract)
SPEC_OC = [(10, 12), (9, 12), (11, 10), (10, 11)]


@pytest.mark.slow
class TestRealOverCommitParity:
    @pytest.mark.parametrize("swap", [False, True])
    def test_preempted_equals_unpreempted_f32(self, tiny, swap):
        cfg, params = tiny
        base = _mk_reqs(3, cfg, SPEC_OC)
        _serve_oc_real(cfg, params, base, over_commit=False)
        reqs = _mk_reqs(3, cfg, SPEC_OC)
        stats, pool = _serve_oc_real(cfg, params, reqs, num_blocks=4,
                                     swap=swap)
        assert stats.preemptions > 0
        if swap:
            assert stats.swapped_blocks > 0
            assert stats.recomputed_tokens == 0
        else:
            assert stats.recomputed_tokens > 0
        for b, r in zip(base, reqs):
            assert b.tokens_out == r.tokens_out, (swap, r.rid)
            assert r.done
        assert pool.blocks_reserved == 0

    def test_priority_tiers_real_model(self, tiny):
        cfg, params = tiny
        reqs = _mk_reqs(5, cfg, SPEC_OC, priorities=[1, 0, 0, 0])
        base = _mk_reqs(5, cfg, SPEC_OC, priorities=[1, 0, 0, 0])
        _serve_oc_real(cfg, params, base, over_commit=False)
        stats, _ = _serve_oc_real(cfg, params, reqs, num_blocks=4)
        assert stats.preemptions > 0
        assert stats.request_latency[0].queue_wait_steps == 0
        assert stats.tier_latency[1].requests == 1
        for b, r in zip(base, reqs):
            assert b.tokens_out == r.tokens_out


@pytest.mark.slow
@pytest.mark.deploy
class TestDeployOverCommitParity:
    """Over-commit preemption on the integer deployment path: calibrated
    int8 KV round-trips storage exactly, so drop-mode recompute and
    swap-mode restore both preserve bit-level greedy parity for both
    kv-bit widths."""

    @pytest.fixture(scope="class")
    def deployed(self):
        from repro.core import Mode, QuantCtx, build_deploy, peg_policy
        from repro.core.pipeline import ptq
        cfg = get_config("gemma2-2b").reduced()
        key = jax.random.PRNGKey(0)
        params = tfm.init_params(cfg, key, stacked=True, dtype=jnp.float32)
        pol = peg_policy(4)
        flat = tfm.init_params(cfg, key, stacked=False, dtype=jnp.float32)
        calib = [{"tokens": jax.random.randint(jax.random.PRNGKey(10),
                                               (2, 8), 0, cfg.vocab_size)}]

        def fwd(p, b, ctx):
            logits, _ = tfm.forward(cfg, p, b["tokens"], ctx=ctx)
            return logits

        qm = ptq(fwd, flat, calib, pol, collect_inputs=True)
        shared = {}
        for site, qp in qm.act_state.items():
            base = ("layer/" + site.split("/", 1)[1]
                    if site.startswith("layer") else site)
            shared.setdefault(base, qp)
        packed, acts = build_deploy(cfg, params, pol, shared)

        def ctx_factory():
            return QuantCtx(policy=pol, mode=Mode.DEPLOY, act_state=shared,
                            deploy_acts=acts)
        return cfg, packed, ctx_factory

    @pytest.mark.parametrize("kv_bits,swap", [(16, False), (8, False),
                                              (8, True)])
    def test_preempted_equals_unpreempted_deploy(self, deployed, kv_bits,
                                                 swap):
        cfg, packed, ctx_factory = deployed
        base = _mk_reqs(9, cfg, SPEC_OC)
        _serve_oc_real(cfg, packed, base, kv_bits=kv_bits,
                       over_commit=False, ctx_factory=ctx_factory)
        reqs = _mk_reqs(9, cfg, SPEC_OC)
        stats, _ = _serve_oc_real(cfg, packed, reqs, kv_bits=kv_bits,
                                  num_blocks=4, swap=swap,
                                  ctx_factory=ctx_factory)
        assert stats.preemptions > 0
        for b, r in zip(base, reqs):
            assert b.tokens_out == r.tokens_out, (kv_bits, swap, r.rid)
