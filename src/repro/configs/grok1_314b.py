"""grok-1-314b [moe] — 8 experts top-2, attention/output logit soft-capping.
[hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ModelConfig
from repro.models.moe import MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,                   # per-expert hidden
    vocab_size=131072,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=32768, activation="gelu",
                  norm_topk=False),
    attn_logit_softcap=30.0,
    final_logit_softcap=30.0,
    embed_scale=True,
    rope_theta=10000.0,
    norm="rmsnorm",
    act="gelu",
    ffn_type="glu",
    tie_embeddings=True,
    sub_quadratic=False,
    source="hf:xai-org/grok-1; unverified",
)
