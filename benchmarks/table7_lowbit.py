"""Paper Table 7 / App. C: low-bit weight & token-embedding quantization.

Rows: W6A32 / W4A32 PTQ, W4A32 AdaRound, W4A8 QAT, W4A8 + 2-bit embeddings
QAT — with the paper's memory-reduction accounting."""
from __future__ import annotations

import dataclasses

from benchmarks.common import (cached_table, eval_qat, eval_task,
                               glue_average, qat_finetune, quantize_and_eval,
                               train_task)
from repro.core import (FP32, QuantizationPolicy, QuantizerConfig,
                        RangeEstimator, low_bit_weight_policy)
from repro.data.synthetic import GLUE_SUITE

# run the QAT rows on a subset to bound CPU time; PTQ rows on all tasks
QAT_TASKS = [t for t in GLUE_SUITE if t.name in
             ("syn-sst2", "syn-mnli", "syn-qnli", "syn-qqp")]


def memory_reduction(weight_bits, embed_bits=None, act_bits=32):
    """Paper's accounting: FP32 checkpoint vs quantized weights+embeddings."""
    e = embed_bits if embed_bits is not None else weight_bits
    # weights ~ embedding fraction of BERT-base: 23.8M of 109M params
    emb_frac = 23.8 / 109.0
    bits = emb_frac * e + (1 - emb_frac) * weight_bits
    return 32.0 / bits


def compute():
    rows = {}
    configs = {
        "FP32": (None, 1.0),
        "W6A32 PTQ": (low_bit_weight_policy(6), memory_reduction(6)),
        "W4A32 PTQ": (low_bit_weight_policy(4), memory_reduction(4)),
        "W4A32 AdaRound": (low_bit_weight_policy(4), memory_reduction(4)),
        "W4A8 QAT": (low_bit_weight_policy(4, act_bits=8),
                     memory_reduction(4)),
        "W4A8 2b-embd QAT": (low_bit_weight_policy(4, act_bits=8,
                                                   embedding_bits=2),
                             memory_reduction(4, embed_bits=2)),
    }
    for label, (pol, mem) in configs.items():
        rows[label] = {"memory_reduction": round(mem, 2)}
        tasks = QAT_TASKS if "QAT" in label else GLUE_SUITE
        for task in tasks:
            params = train_task(task)
            if pol is None:
                rows[label][task.name] = eval_task(task, params)
            elif "QAT" in label:
                qp, ctxf = qat_finetune(task, params, pol)
                rows[label][task.name] = eval_qat(task, qp, ctxf)
            else:
                rows[label][task.name] = quantize_and_eval(
                    task, params, pol, adaround_ffn="AdaRound" in label)
        rows[label]["avg"] = glue_average(
            {k: v for k, v in rows[label].items()
             if k not in ("memory_reduction", "avg")})
    return rows


def run():
    return cached_table("table7_lowbit", compute)


def report(rows):
    lines = ["method,memory_reduction,avg_metric,per_task"]
    for label, scores in rows.items():
        per_task = ";".join(f"{k}={v:.1f}" for k, v in scores.items()
                            if k not in ("memory_reduction", "avg"))
        lines.append(f"\"{label}\",x{scores['memory_reduction']},"
                     f"{scores['avg']:.2f},\"{per_task}\"")
    return "\n".join(lines)


if __name__ == "__main__":
    print(report(run()))
