"""Paper Table 4: mixed-precision PTQ — progressively keep the problematic
tensors in 16-bit (residual FFN sum -> + FFN in/out -> + final output)."""
from __future__ import annotations

from benchmarks.common import (cached_table, eval_task, quantize_and_eval,
                               train_task)
from repro.core import mixed_precision_policy, w8a8_policy
from repro.data.synthetic import GLUE_SUITE

TASKS = [t for t in GLUE_SUITE if t.name in
         ("syn-sst2", "syn-mnli", "syn-qnli", "syn-qqp")]

CONFIGS = {
    "W8A8 PTQ": ("w8a8", {}),
    "MP-PTQ (16b residual sum)": ("mp", dict(ffn_io_16bit=False,
                                             output_16bit=False)),
    "MP-PTQ (+16b FFN in/out)": ("mp", dict(ffn_io_16bit=True,
                                            output_16bit=False)),
    "MP-PTQ (+16b final output)": ("mp", dict(ffn_io_16bit=True,
                                              output_16bit=True)),
}


def compute():
    rows = {"FP32": {}}
    for task in TASKS:
        params = train_task(task)
        rows["FP32"][task.name] = eval_task(task, params)
        for label, (kind, kw) in CONFIGS.items():
            pol = w8a8_policy() if kind == "w8a8" \
                else mixed_precision_policy(**kw)
            rows.setdefault(label, {})[task.name] = \
                quantize_and_eval(task, params, pol)
    return rows


def run():
    return cached_table("table4_mixed_precision", compute)


def report(rows):
    tasks = [t.name for t in TASKS]
    lines = ["config," + ",".join(tasks)]
    for label, scores in rows.items():
        lines.append(f"\"{label}\"," +
                     ",".join(f"{scores[t]:.2f}" for t in tasks))
    return "\n".join(lines)


if __name__ == "__main__":
    print(report(run()))
